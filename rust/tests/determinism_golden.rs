//! Golden determinism: the parallel round engine must be invisible.
//!
//! The contract (coordinator/README.md): for any method, any server
//! shard count, any scheduling policy, and any thread count,
//! `Parallelism::Threads(n)` with any `SchedPolicy` produces a
//! **bit-identical** run to `Parallelism::Sequential` — same
//! `RunRecord` JSON (every loss, byte count, and simulated timestamp),
//! same timeline span sequence, same communication ledger, same final
//! model states. These tests pin that contract over the mock engine for
//! all four methods, for the sharded server phase
//! (`server_shards` ∈ {1, 2, n}), and for every dealing policy.
//! Changing the *shard count* or the *shard map* is allowed (and
//! expected) to change results — which is exactly why both are part of
//! `RunSpec::key` — but the thread count and dealing policy never may.

use cse_fsl::comm::accounting::CommLedger;
use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind, TrainConfig};
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::exp::common::run_to_json;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::sched::SchedPolicy;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::sim::timeline::Timeline;
use cse_fsl::util::prng::Rng;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn setup_net<'a>(
    train: &'a Dataset,
    test: &'a Dataset,
    n_clients: usize,
    net: NetModel,
) -> TrainerSetup<'a> {
    let mut rng = Rng::new(7);
    TrainerSetup {
        train,
        test,
        partition: iid(train, n_clients, &mut rng),
        net,
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "golden".to_string(),
    }
}

fn setup<'a>(train: &'a Dataset, test: &'a Dataset, n_clients: usize) -> TrainerSetup<'a> {
    setup_net(train, test, n_clients, NetModel::edge_default())
}

/// Everything observable about a finished run.
struct Fingerprint {
    json: String,
    timeline: Timeline,
    ledger: CommLedger,
    client_models: Vec<Vec<f32>>,
    client_aux: Vec<Vec<f32>>,
    server_copies: Vec<Vec<f32>>,
    server_updates: u64,
    shard_updates: Vec<u64>,
    shard_of: Vec<usize>,
}

#[allow(clippy::too_many_arguments)]
fn run_sched(
    method: Method,
    h: usize,
    participation: usize,
    arrival: ArrivalOrder,
    parallelism: Parallelism,
    rounds: usize,
    server_shards: usize,
    sched: SchedPolicy,
    shard_map: ShardMapKind,
    net: NetModel,
    train: &Dataset,
    test: &Dataset,
) -> Fingerprint {
    let e = MockEngine::small(42);
    let cfg = TrainConfig {
        h,
        participation,
        arrival,
        parallelism,
        server_shards,
        sched,
        shard_map,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::new(method)
    }
    .with_rounds(rounds);
    let mut tr = Trainer::new(&e, cfg, setup_net(train, test, 5, net)).unwrap();
    let rec = tr.run().unwrap();
    Fingerprint {
        json: run_to_json(&rec).pretty(),
        timeline: tr.timeline.clone(),
        ledger: tr.ledger.clone(),
        client_models: tr.clients.iter().map(|c| c.xc.clone()).collect(),
        client_aux: tr.clients.iter().map(|c| c.ac.clone()).collect(),
        server_copies: tr.server.copies.clone(),
        server_updates: tr.server.updates,
        shard_updates: tr.server.shard_updates.clone(),
        shard_of: (0..tr.clients.len()).map(|c| tr.server.shard_map.shard_of(c)).collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    method: Method,
    h: usize,
    participation: usize,
    arrival: ArrivalOrder,
    parallelism: Parallelism,
    rounds: usize,
    server_shards: usize,
    train: &Dataset,
    test: &Dataset,
) -> Fingerprint {
    run_sched(
        method,
        h,
        participation,
        arrival,
        parallelism,
        rounds,
        server_shards,
        SchedPolicy::RoundRobin,
        ShardMapKind::Contiguous,
        NetModel::edge_default(),
        train,
        test,
    )
}

fn assert_identical(seq: &Fingerprint, par: &Fingerprint, ctx: &str) {
    // Byte-identical serialized RunRecord is the headline contract.
    assert_eq!(seq.json.as_bytes(), par.json.as_bytes(), "{ctx}: RunRecord JSON diverged");
    assert_eq!(seq.timeline, par.timeline, "{ctx}: timeline span sequence diverged");
    assert_eq!(seq.ledger, par.ledger, "{ctx}: communication ledger diverged");
    assert_eq!(seq.client_models, par.client_models, "{ctx}: client models diverged");
    assert_eq!(seq.client_aux, par.client_aux, "{ctx}: aux models diverged");
    assert_eq!(seq.server_copies, par.server_copies, "{ctx}: server copies diverged");
    assert_eq!(seq.server_updates, par.server_updates, "{ctx}: update count diverged");
    assert_eq!(seq.shard_updates, par.shard_updates, "{ctx}: per-shard counts diverged");
    assert_eq!(seq.shard_of, par.shard_of, "{ctx}: shard map diverged");
}

#[test]
fn threads_bit_identical_to_sequential_for_all_methods() {
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    for method in Method::ALL {
        let h = if method.supports_h() { 2 } else { 1 };
        let seq = run(
            method,
            h,
            0,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            10,
            1,
            &train,
            &test,
        );
        for threads in [1usize, 2, 4, 8] {
            let par = run(
                method,
                h,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Threads(threads),
                10,
                1,
                &train,
                &test,
            );
            assert_identical(&seq, &par, &format!("{method} threads={threads}"));
        }
    }
}

#[test]
fn sharded_golden_bit_identical_across_thread_counts() {
    // The sharded server phase (k copies, k event-loop executors) must
    // keep the contract at every k for both single-copy methods —
    // including k = n, where each client has a private shard.
    let train = dataset(120, 9);
    let test = dataset(24, 10);
    for method in [Method::CseFsl, Method::FslOc] {
        let h = if method.supports_h() { 2 } else { 1 };
        for shards in [1usize, 2, 5] {
            let seq = run(
                method,
                h,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Sequential,
                10,
                shards,
                &train,
                &test,
            );
            for threads in [1usize, 4] {
                let par = run(
                    method,
                    h,
                    0,
                    ArrivalOrder::ByDelay,
                    Parallelism::Threads(threads),
                    10,
                    shards,
                    &train,
                    &test,
                );
                assert_identical(
                    &seq,
                    &par,
                    &format!("{method} shards={shards} threads={threads}"),
                );
            }
            // Per-shard counts: one counter per copy, conserving the
            // total, and every shard actually serves its client group.
            assert_eq!(seq.shard_updates.len(), shards);
            assert_eq!(seq.shard_updates.iter().sum::<u64>(), seq.server_updates);
            assert!(
                seq.shard_updates.iter().all(|&u| u > 0),
                "{method} shards={shards}: idle shard in {:?}",
                seq.shard_updates
            );
            assert_eq!(seq.server_copies.len(), shards);
        }
    }
}

#[test]
fn shards_one_bit_identical_to_default_single_copy() {
    // --server-shards 1 must be the historical single-copy run exactly:
    // the default config (which never mentions shards) and an explicit
    // k=1 produce the same fingerprint.
    let train = dataset(120, 11);
    let test = dataset(24, 12);
    let explicit = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        8,
        1,
        &train,
        &test,
    );
    let e = MockEngine::small(42);
    // Built without touching server_shards at all.
    let cfg = TrainConfig {
        h: 2,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::new(Method::CseFsl)
    }
    .with_rounds(8);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
    let rec = tr.run().unwrap();
    assert_eq!(
        explicit.json,
        run_to_json(&rec).pretty(),
        "default config must equal explicit k=1"
    );
}

#[test]
fn shard_count_changes_results() {
    // Sharding is a *semantic* knob (disjoint shard trajectories between
    // aggregations), not a scheduling knob — this is why server_shards
    // is part of RunSpec::key while parallelism is not.
    let train = dataset(120, 13);
    let test = dataset(24, 14);
    let k1 = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        1,
        &train,
        &test,
    );
    let k2 = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        2,
        &train,
        &test,
    );
    assert_ne!(k1.json, k2.json, "k=2 must not silently replay the k=1 run");
}

#[test]
fn golden_holds_under_partial_participation() {
    // k-of-n sampling exercises non-contiguous sorted participant sets
    // in the fan-out (disjoint-borrow collection + round-robin buckets).
    let train = dataset(120, 3);
    let test = dataset(24, 4);
    for method in [Method::CseFsl, Method::FslMc] {
        let seq = run(
            method,
            1,
            3,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            12,
            1,
            &train,
            &test,
        );
        let par = run(
            method,
            1,
            3,
            ArrivalOrder::ByDelay,
            Parallelism::Threads(4),
            12,
            1,
            &train,
            &test,
        );
        assert_identical(&seq, &par, &format!("{method} participation=3"));
    }
    // Sharded + partial participation: some shards may sit idle in a
    // round; determinism must survive the uneven lane loads.
    let seq = run(
        Method::CseFsl,
        2,
        2,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        12,
        2,
        &train,
        &test,
    );
    let par = run(
        Method::CseFsl,
        2,
        2,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        12,
        2,
        &train,
        &test,
    );
    assert_identical(&seq, &par, "CSE_FSL shards=2 participation=2");
}

#[test]
fn golden_holds_under_shuffled_arrival_order() {
    // The Fig. 6 shuffled arm consumes the trainer RNG *after* the
    // fan-out; the parallel engine must leave that stream untouched.
    let train = dataset(120, 5);
    let test = dataset(24, 6);
    let seq = run(
        Method::CseFsl,
        3,
        0,
        ArrivalOrder::Shuffled,
        Parallelism::Sequential,
        9,
        1,
        &train,
        &test,
    );
    let par = run(
        Method::CseFsl,
        3,
        0,
        ArrivalOrder::Shuffled,
        Parallelism::Threads(3),
        9,
        1,
        &train,
        &test,
    );
    assert_identical(&seq, &par, "CSE_FSL shuffled arrivals");
}

#[test]
fn sched_policies_bit_identical_across_threads() {
    // Acceptance pin: RoundRobin / CostWeighted / WorkStealing produce
    // bit-identical RunRecords at threads {1, 4}, for a local-update
    // method and a SplitFed baseline (both fan-out shapes).
    let train = dataset(120, 15);
    let test = dataset(24, 16);
    for method in [Method::CseFsl, Method::FslMc] {
        let h = if method.supports_h() { 2 } else { 1 };
        let reference = run(
            method,
            h,
            0,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            10,
            1,
            &train,
            &test,
        );
        for sched in SchedPolicy::ALL {
            for threads in [1usize, 4] {
                let par = run_sched(
                    method,
                    h,
                    0,
                    ArrivalOrder::ByDelay,
                    Parallelism::Threads(threads),
                    10,
                    1,
                    sched,
                    ShardMapKind::Contiguous,
                    NetModel::edge_default(),
                    &train,
                    &test,
                );
                assert_identical(
                    &reference,
                    &par,
                    &format!("{method} sched={sched} threads={threads}"),
                );
            }
        }
    }
    // The sharded server phase fans its drain loops through the same
    // scheduler: pin the policies there too.
    let reference = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        2,
        &train,
        &test,
    );
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_sched(
                Method::CseFsl,
                2,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Threads(threads),
                10,
                2,
                sched,
                ShardMapKind::Contiguous,
                NetModel::edge_default(),
                &train,
                &test,
            );
            assert_identical(
                &reference,
                &par,
                &format!("CSE_FSL shards=2 sched={sched} threads={threads}"),
            );
        }
    }
}

#[test]
fn balanced_shard_map_deterministic_and_result_changing() {
    // The balanced ShardMap (LPT on client costs) keeps the
    // bit-determinism contract — sequential and threaded runs agree for
    // every policy — while its *assignment* (and therefore results)
    // legitimately differs from contiguous, which is why the map kind
    // joins RunSpec::key.
    let train = dataset(120, 17);
    let test = dataset(24, 18);
    let run_map = |map: ShardMapKind, par: Parallelism, sched: SchedPolicy| {
        run_sched(
            Method::CseFsl,
            2,
            0,
            ArrivalOrder::ByDelay,
            par,
            10,
            2,
            sched,
            map,
            NetModel::heavy_tailed(),
            &train,
            &test,
        )
    };
    let bal = run_map(ShardMapKind::Balanced, Parallelism::Sequential, SchedPolicy::RoundRobin);
    // The balanced partition covers every client and leaves no shard
    // empty (LPT over sanitized positive costs).
    assert_eq!(bal.shard_of.len(), 5);
    for shard in 0..2 {
        assert!(
            bal.shard_of.iter().any(|&s| s == shard),
            "empty shard {shard} in {:?}",
            bal.shard_of
        );
    }
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_map(ShardMapKind::Balanced, Parallelism::Threads(threads), sched);
            assert_identical(
                &bal,
                &par,
                &format!("balanced sched={sched} threads={threads}"),
            );
        }
    }
    let cont =
        run_map(ShardMapKind::Contiguous, Parallelism::Sequential, SchedPolicy::RoundRobin);
    // Under the heavy-tailed profile the LPT assignment regroups the
    // clients; whenever it does, results must change with it (the
    // RunSpec::key argument). With 5 heterogeneous client costs the
    // assignments virtually always differ — but guard anyway so the
    // assertion can never go stale silently.
    if bal.shard_of != cont.shard_of {
        assert_ne!(bal.json, cont.json, "regrouped shards must change results");
    } else {
        assert_eq!(bal.json, cont.json, "identical maps must replay identical runs");
    }
}

#[test]
fn parallel_runs_are_reproducible_across_invocations() {
    // Threads(n) vs Threads(n) with identical configs: scheduling noise
    // must never leak into results.
    let train = dataset(80, 7);
    let test = dataset(16, 8);
    let a = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        8,
        2,
        &train,
        &test,
    );
    let b = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        8,
        2,
        &train,
        &test,
    );
    assert_identical(&a, &b, "Threads(4) shards=2 repeat");
}
