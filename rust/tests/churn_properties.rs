//! Properties of the churn & reliability subsystem (sim/churn):
//!
//! - `Iid{p}` replays the legacy population `availability` knob's draw
//!   stream bit-identically, end to end — the run's dropped-client
//!   counter equals a hand replay of the removed filter's exact splits.
//! - `Quorum { min_frac: 1.0, resample: false }` is byte-identical to
//!   `WaitAll` (the guard may never draw when it takes no action), and
//!   flipping `resample` on is a live axis.
//! - The Markov on/off model's realized occupancy converges to its
//!   stationary rate `p_up / (p_up + p_down)`, and availability at
//!   `(t, id)` is a pure function of `(t, id)` — out-of-order queries
//!   cannot perturb it.
//! - Mid-round failures complete cleanly and reproduce bit-for-bit.
//! - The live ledger matches the realized closed forms in
//!   `comm::accounting::predict::realized_kind_bytes` for random
//!   churn × codec × method draws (the churn-proof ledger == predict
//!   contract).

use cse_fsl::comm::accounting::{predict, WireSizes};
use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::{ClientUpdate, Compression, Method, MethodSpec};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::exp::common::run_to_json;
use cse_fsl::metrics::recorder::RunRecord;
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sim::churn::{ChurnConfig, ChurnModel, ChurnState, ResiliencePolicy};
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn config(seed: u64, rounds: usize) -> TrainConfig {
    TrainConfig {
        participation: 0,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        seed,
        ..TrainConfig::new(Method::CseFsl).with_h(2)
    }
    .with_rounds(rounds)
}

/// One resident run over 5 IID clients; returns the record.
fn run_resident(cfg: TrainConfig) -> RunRecord {
    let e = MockEngine::small(42);
    let train = generate(&spec(), 120, 1);
    let test = generate(&spec(), 24, 2);
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition: iid(&train, 5, &mut Rng::new(7)),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "churn".to_string(),
    };
    let mut tr = Trainer::new(&e, cfg, setup).unwrap();
    tr.run().unwrap()
}

#[test]
fn iid_run_replays_the_legacy_availability_stream_end_to_end() {
    // The removed population knob filtered each round's cohort with
    //   avail_root = Rng::new(seed).split_str("availability");
    //   round_avail = avail_root.split(t);
    //   retain(|&i| round_avail.split(i).uniform() < p)
    // With participation 0 every round plans all 5 clients, so the
    // run's dropped counter must equal the hand replay exactly: the
    // Iid model consumes the very same draws.
    let (seed, rounds, p) = (9u64, 12usize, 0.6f64);
    let cfg = config(seed, rounds).with_churn(ChurnConfig {
        model: ChurnModel::Iid { p },
        ..ChurnConfig::default()
    });
    let rec = run_resident(cfg);
    let avail_root = Rng::new(seed).split_str("availability");
    let mut expected = 0u64;
    for t in 1..=rounds {
        let round_avail = avail_root.split(t as u64);
        for id in 0..5u64 {
            if round_avail.split(id).uniform() >= p {
                expected += 1;
            }
        }
    }
    assert!(expected > 0, "p=0.6 over 60 draws must drop someone");
    assert_eq!(
        rec.clients_dropped, expected,
        "Iid{{{p}}} diverged from the legacy availability stream"
    );
    assert_eq!(rec.rounds.len(), rounds);
}

#[test]
fn quorum_guard_that_takes_no_action_is_byte_invisible() {
    // Under full availability the guard must never even draw: a
    // resampling quorum config is byte-identical to the default.
    let baseline = run_to_json(&run_resident(config(1, 12))).pretty();
    let guarded = config(1, 12).with_churn(ChurnConfig {
        policy: ResiliencePolicy::Quorum { min_frac: 1.0, resample: true },
        ..ChurnConfig::default()
    });
    assert_eq!(
        baseline,
        run_to_json(&run_resident(guarded)).pretty(),
        "a quorum over a full cohort must not change a single byte"
    );
    // Under real churn, Quorum{1.0, resample: false} never acts either:
    // byte-identical to WaitAll on the same model. The cohort samples
    // 3 of 5 so the resampling variant below has someone to admit —
    // at participation 0 every available client is already in the
    // cohort and no replacement can ever exist.
    let churned = |policy| {
        TrainConfig { participation: 3, ..config(1, 12) }.with_churn(ChurnConfig {
            model: ChurnModel::Iid { p: 0.6 },
            policy,
            ..ChurnConfig::default()
        })
    };
    let wait_all = run_resident(churned(ResiliencePolicy::WaitAll));
    let full_quorum = run_resident(churned(ResiliencePolicy::Quorum {
        min_frac: 1.0,
        resample: false,
    }));
    assert_eq!(
        run_to_json(&wait_all).pretty(),
        run_to_json(&full_quorum).pretty(),
        "Quorum{{1.0, resample: false}} must be byte-identical to WaitAll"
    );
    // Flipping resample on is a live axis: replacements are admitted
    // and the trajectory forks.
    let resampled = run_resident(churned(ResiliencePolicy::Quorum {
        min_frac: 1.0,
        resample: true,
    }));
    assert!(resampled.clients_replaced > 0, "resampling below quorum must replace");
    assert_ne!(
        run_to_json(&wait_all).pretty(),
        run_to_json(&resampled).pretty(),
        "resampling must change results"
    );
}

#[test]
fn markov_occupancy_converges_to_the_stationary_rate() {
    for (p_up, p_down) in [(0.3f64, 0.1f64), (0.2, 0.2)] {
        let model = ChurnModel::MarkovOnOff { p_up, p_down };
        let mut st = ChurnState::new(&Rng::new(11));
        let (clients, rounds) = (400usize, 200usize);
        let mut up = 0u64;
        for id in 0..clients {
            for t in 0..rounds {
                if st.is_available(&model, t, id) {
                    up += 1;
                }
            }
        }
        let occupancy = up as f64 / (clients * rounds) as f64;
        let stationary = p_up / (p_up + p_down);
        assert!(
            (occupancy - stationary).abs() < 0.02,
            "p_up={p_up} p_down={p_down}: occupancy {occupancy} vs stationary {stationary}"
        );
    }
    // Purity: the state at (t, id) is a function of (t, id) alone — a
    // query behind the memoized frontier agrees with a fresh evaluator,
    // and the memo it leaves behind stays consistent.
    let model = ChurnModel::MarkovOnOff { p_up: 0.3, p_down: 0.1 };
    let mut warm = ChurnState::new(&Rng::new(11));
    for id in 0..32usize {
        let _ = warm.is_available(&model, 10, id);
    }
    let mut fresh = ChurnState::new(&Rng::new(11));
    for t in [3usize, 7, 10, 2, 10] {
        for id in 0..32usize {
            assert_eq!(
                warm.is_available(&model, t, id),
                fresh.is_available(&model, t, id),
                "t={t} id={id}: out-of-order query diverged"
            );
        }
    }
}

#[test]
fn mid_round_failures_complete_and_reproduce_bit_for_bit() {
    let failing = || {
        config(3, 12).with_churn(ChurnConfig {
            fail_rate: 0.5,
            ..ChurnConfig::default()
        })
    };
    let a = run_resident(failing());
    assert_eq!(a.rounds.len(), 12);
    assert!(a.partial_failures > 0, "fail_rate 0.5 over 60 slots must kill someone");
    assert!(a.rounds.iter().all(|r| r.train_loss.is_finite()));
    // A failed client costs wire bytes but no model progress — the run
    // still differs from the failure-free baseline (fewer uploads
    // reach the server) and reproduces exactly.
    let b = run_resident(failing());
    assert_eq!(run_to_json(&a).pretty(), run_to_json(&b).pretty());
    assert_ne!(
        run_to_json(&a).pretty(),
        run_to_json(&run_resident(config(3, 12))).pretty(),
        "failures must change results"
    );
}

#[test]
fn prop_churned_ledger_matches_the_realized_closed_forms() {
    prop::check("churned ledger == realized closed forms", |rng| {
        let compression = match rng.below(3) {
            0 => Compression::None,
            1 => Compression::Quantize { bits: 1 + rng.below(16) as u8 },
            _ => Compression::TopK { frac: (1 + rng.below(20) as u32) as f32 / 20.0 },
        };
        let churn = if rng.below(5) == 0 {
            // Keep the degenerate point in rotation: the realized form
            // must collapse to the a-priori one on unchurned runs.
            ChurnConfig::default()
        } else {
            let model = match rng.below(4) {
                0 => ChurnModel::Iid { p: 0.4 + 0.6 * rng.uniform() },
                1 => ChurnModel::Diurnal {
                    amplitude: rng.uniform(),
                    period_rounds: 1 + rng.below(6) as usize,
                    phase: 0.25,
                },
                2 => ChurnModel::MarkovOnOff {
                    p_up: 0.2 + 0.8 * rng.uniform(),
                    p_down: 0.5 * rng.uniform(),
                },
                _ => ChurnModel::Correlated {
                    clusters: 1 + rng.below(3) as usize,
                    p_outage: 0.4 * rng.uniform(),
                },
            };
            let policy = match rng.below(3) {
                0 => ResiliencePolicy::WaitAll,
                1 => ResiliencePolicy::Cutoff { secs: 0.05 * rng.uniform() },
                _ => ResiliencePolicy::Quorum {
                    min_frac: 0.5 + 0.5 * rng.uniform(),
                    resample: rng.below(2) == 0,
                },
            };
            let fail_rate = if rng.below(2) == 0 { 0.0 } else { 0.4 * rng.uniform() };
            ChurnConfig { model, fail_rate, policy }
        };
        let n = 1 + rng.below(4) as usize;
        let method = Method::ALL[rng.below(4) as usize];
        let rounds = 1 + rng.below(6) as usize;
        let agg_every = 1 + rng.below(rounds as u64 + 2) as usize;
        let e = MockEngine::small(rng.next_u64());
        let train = generate(&spec(), n * 16, rng.next_u64());
        let test = generate(&spec(), 8, rng.next_u64());
        let mut cfg = TrainConfig {
            rounds,
            agg_every,
            eval_every: 0,
            ..TrainConfig::new(method).with_compression(compression)
        }
        .with_churn(churn);
        if rng.below(4) == 0 {
            // Fold the estimator rule into the draw space: alignment
            // round trips must stay ledger-exact under churn too.
            cfg.spec = MethodSpec {
                update: ClientUpdate::SageEstimate {
                    align_every: 1 + rng.below(3) as usize,
                    clip: 0.0,
                },
                ..cfg.spec
            };
        }
        let mspec = cfg.spec;
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition: iid(&train, n, &mut Rng::new(rng.next_u64())),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "prop".into(),
        };
        let mut tr = Trainer::new(&e, cfg, setup)?;
        tr.run().map_err(|e| e.to_string())?;
        let wires = WireSizes::new(e.smashed_len, e.client_size(), e.aux_size());
        let realized =
            predict::RealizedCounts::from_ledger(&tr.ledger, tr.churn_stats.partial_failures);
        let expected = predict::realized_kind_bytes(
            mspec.traffic(),
            compression,
            e.batch as u64,
            &wires,
            &realized,
        );
        for (kind, bytes) in expected {
            prop_assert!(
                tr.ledger.bytes_of(kind) == bytes,
                "{mspec:?} {compression} n={n} rounds={rounds} churn={churn:?}: \
                 {kind:?} measured {} != realized closed form {bytes}",
                tr.ledger.bytes_of(kind)
            );
        }
        if churn.is_default() {
            // No churn: the realized counts ARE the full-participation
            // closed form's.
            let full = predict::RealizedCounts::full(
                mspec.traffic(),
                n as u64,
                rounds as u64,
                agg_every as u64,
            );
            prop_assert!(
                realized == full,
                "unchurned realized counts {realized:?} != full-participation {full:?}"
            );
        }
        Ok(())
    });
}
