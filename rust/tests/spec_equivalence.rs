//! Spec/preset equivalence: the `MethodSpec` refactor must be invisible
//! for the four paper presets and genuinely open everywhere else.
//!
//! 1. **Capability-matrix equivalence** — every `Method::ALL` preset's
//!    spec reproduces the pre-refactor capability matrix exactly
//!    (per-client copies?, aux?, grad downlink?, h>1?, default clip),
//!    checked against the live trainer, not just the spec accessors.
//! 2. **Preset-path identity** — running `TrainConfig::new(method)`
//!    (the preset constructor) and `TrainConfig::from_spec(<the same
//!    axes written out by hand>)` produces bit-identical `RunRecord`s:
//!    there is no hidden method-identity branch left anywhere in the
//!    trainer.
//! 3. **Openness** — the spec-only `AuxLocal × Period(h) × PerClient`
//!    scenario runs end-to-end through the experiment harness (spec →
//!    cache key → mock engine → cached record), under its own canonical
//!    cache key, distinct from every preset.

use cse_fsl::comm::accounting::MsgKind;
use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind, TrainConfig};
use cse_fsl::coordinator::methods::{
    ClientUpdate, Compression, Method, MethodSpec, ServerTopology, UploadSchedule,
};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::exp::common::{
    cifar_workload, femnist_workload, run_to_json, Dist, EngineChoice, Harness, RunSpec,
    Scale,
};
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::sched::SchedPolicy;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn setup<'a>(train: &'a Dataset, test: &'a Dataset, n_clients: usize) -> TrainerSetup<'a> {
    let mut rng = Rng::new(7);
    TrainerSetup {
        train,
        test,
        partition: iid(train, n_clients, &mut rng),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "spec-eq".to_string(),
    }
}

/// Run one config over the mock engine; return (record JSON, trainer
/// observables that matter for equivalence).
fn run_cfg(
    cfg: TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> (String, Vec<Vec<f32>>, u64, u64, u64) {
    let e = MockEngine::small(42);
    let mut tr = Trainer::new(&e, cfg, setup(train, test, 4)).unwrap();
    let rec = tr.run().unwrap();
    (
        run_to_json(&rec).pretty(),
        tr.server.copies.clone(),
        tr.server.updates,
        tr.ledger.bytes_of(MsgKind::GradDownload),
        tr.ledger.bytes_of(MsgKind::AuxModelUpload),
    )
}

/// The hand-written axes of each preset, copied from the paper's
/// Section VI-A table — deliberately NOT built via `Method::spec()`, so
/// a drifting preset definition fails here.
fn hand_spec(method: Method) -> MethodSpec {
    match method {
        Method::FslMc => MethodSpec {
            update: ClientUpdate::ServerGrad { clip: 0.0 },
            upload: UploadSchedule::EveryBatch,
            topology: ServerTopology::PerClient,
            compression: Compression::None,
        },
        Method::FslOc => MethodSpec {
            update: ClientUpdate::ServerGrad { clip: 1.0 },
            upload: UploadSchedule::EveryBatch,
            topology: ServerTopology::Shared,
            compression: Compression::None,
        },
        Method::FslAn => MethodSpec {
            update: ClientUpdate::AuxLocal,
            upload: UploadSchedule::EveryBatch,
            topology: ServerTopology::PerClient,
            compression: Compression::None,
        },
        Method::CseFsl => MethodSpec {
            update: ClientUpdate::AuxLocal,
            upload: UploadSchedule::EveryBatch,
            topology: ServerTopology::Shared,
            compression: Compression::None,
        },
    }
}

#[test]
fn preset_specs_reproduce_old_capability_matrix_live() {
    // The matrix as the old Method enum hardcoded it, observed through
    // live trainer behavior: copy counts, wire kinds, and h validity.
    let train = dataset(64, 31);
    let test = dataset(16, 32);
    let expect = [
        // (method, server copies at n=4, grad downlink?, aux upload?)
        // — the pre-refactor matrix, hardcoded (NOT derived from the
        // spec, so a drifted preset definition fails here).
        (Method::FslMc, 4usize, true, false),
        (Method::FslOc, 1, true, false),
        (Method::FslAn, 4, false, true),
        (Method::CseFsl, 1, false, true),
    ];
    for (method, copies, grad, aux) in expect {
        let cfg = TrainConfig { agg_every: 3, eval_every: 0, ..TrainConfig::new(method) }
            .with_rounds(6);
        let (_, server_copies, updates, grad_bytes, aux_bytes) =
            run_cfg(cfg, &train, &test);
        assert_eq!(server_copies.len(), copies, "{method} copy count");
        assert!(updates > 0, "{method} must update");
        assert_eq!(grad_bytes > 0, grad, "{method} grad downlink");
        assert_eq!(aux_bytes > 0, aux, "{method} aux exchange");
        assert_eq!(
            matches!(method.spec().update, ClientUpdate::ServerGrad { .. }),
            grad,
            "{method} update axis vs wire behavior"
        );
        // Old supports_h: only CSE_FSL could take h > 1 *within the
        // preset space*; the SplitFed presets still reject it outright.
        let h_cfg = TrainConfig::new(method).with_h(3);
        match method {
            Method::CseFsl => assert!(h_cfg.validate(4).is_ok()),
            Method::FslAn => {
                // Newly VALID (the open API), but a spec-only point.
                assert!(h_cfg.validate(4).is_ok());
                assert_eq!(h_cfg.spec.preset(), None);
            }
            _ => assert!(h_cfg.validate(4).is_err(), "{method} must reject h>1"),
        }
        // Default clip: the paper's OC-only stabilizer.
        let expect_clip = if method == Method::FslOc { 1.0 } else { 0.0 };
        assert_eq!(method.spec().clip(), expect_clip, "{method} clip");
    }
}

#[test]
fn preset_constructor_bit_identical_to_hand_assembled_spec() {
    // There is no method identity left in the trainer: the preset
    // constructor and the raw axes produce the same bits, for every
    // preset and (for CSE_FSL) a period on top.
    let train = dataset(96, 33);
    let test = dataset(16, 34);
    for method in Method::ALL {
        let via_preset = run_cfg(
            TrainConfig { agg_every: 4, lr0: 1.0, ..TrainConfig::new(method) }.with_rounds(8),
            &train,
            &test,
        );
        let via_spec = run_cfg(
            TrainConfig { agg_every: 4, lr0: 1.0, ..TrainConfig::from_spec(hand_spec(method)) }
                .with_rounds(8),
            &train,
            &test,
        );
        assert_eq!(via_preset.0, via_spec.0, "{method}: RunRecord JSON diverged");
        assert_eq!(via_preset.1, via_spec.1, "{method}: server copies diverged");
        assert_eq!(via_preset.2, via_spec.2, "{method}: update counts diverged");
    }
    // CSE_FSL with a period, both ways.
    let via_preset = run_cfg(
        TrainConfig { agg_every: 4, ..TrainConfig::new(Method::CseFsl).with_h(2) }
            .with_rounds(8),
        &train,
        &test,
    );
    let via_spec = run_cfg(
        TrainConfig {
            agg_every: 4,
            ..TrainConfig::from_spec(MethodSpec {
                upload: UploadSchedule::Period(2),
                ..hand_spec(Method::CseFsl)
            })
        }
        .with_rounds(8),
        &train,
        &test,
    );
    assert_eq!(via_preset.0, via_spec.0, "CSE_FSL h=2: RunRecord JSON diverged");
}

#[test]
fn adaptive_schedule_runs_and_differs_from_fixed_periods() {
    // The third upload-schedule variant end-to-end: deterministic,
    // reproducible, and a genuinely different trajectory from both
    // fixed endpoints (h0 and h_max).
    let train = dataset(96, 35);
    let test = dataset(16, 36);
    let adaptive = MethodSpec {
        upload: UploadSchedule::AdaptivePeriod { h0: 1, h_max: 4, double_every: 3 },
        ..Method::CseFsl.spec()
    };
    let run_spec = |s: MethodSpec| {
        run_cfg(
            TrainConfig { agg_every: 4, eval_every: 0, ..TrainConfig::from_spec(s) }
                .with_rounds(9),
            &train,
            &test,
        )
    };
    let a1 = run_spec(adaptive);
    let a2 = run_spec(adaptive);
    assert_eq!(a1.0, a2.0, "adaptive schedule must be deterministic");
    let fixed_lo = run_spec(Method::CseFsl.spec());
    let fixed_hi = run_spec(Method::CseFsl.spec().with_period(4));
    assert_ne!(a1.0, fixed_lo.0, "adaptive must leave the h=1 trajectory");
    assert_ne!(a1.0, fixed_hi.0, "adaptive must not equal the h_max trajectory");
    assert_eq!(a1.3, 0, "aux-local rule never downlinks grads");
}

#[test]
fn novel_scenario_runs_end_to_end_through_the_harness() {
    // AuxLocal × Period(2) × PerClient through the full experiment
    // path: RunSpec validation, canonical cache key, mock engine run,
    // cache replay. This is the acceptance scenario — "FSL_AN with
    // h > 1" — expressible only as a spec.
    let dir = std::env::temp_dir().join(format!(
        "cse_fsl_spec_eq_{}_{}",
        std::process::id(),
        line!()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let mut wl = femnist_workload(Scale::Quick);
    wl.rounds = 4;
    let base = RunSpec {
        dataset: "femnist".into(),
        aux: "cnn8".into(),
        method: Method::FslAn.spec().with_period(2),
        n_clients: 4,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: 0.05,
        seed: 1,
        workload: wl,
        parallelism: Parallelism::Sequential,
        server_shards: 1,
        sched: SchedPolicy::RoundRobin,
        shard_map: ShardMapKind::Contiguous,
    };
    assert!(base.validate().is_ok());
    assert!(base.key().contains("-aux+p2+pc-h2-"), "{}", base.key());
    let novel = h.run_cached(&base).unwrap();
    assert_eq!(novel.rounds.len(), 4);
    assert_eq!(novel.label, "aux+p2+pc");
    // Cached under the canonical spec key; the cache replays bitwise.
    let cache = dir.join("cache").join("mock").join(format!("{}.json", base.key()));
    assert!(cache.is_file(), "missing cache entry {}", cache.display());
    let replay = h.run_cached(&base).unwrap();
    assert_eq!(run_to_json(&novel).pretty(), run_to_json(&replay).pretty());
    // Its preset neighbours are distinct cached runs with the
    // historical keys.
    let an = RunSpec { method: Method::FslAn.spec(), ..base.clone() };
    assert!(an.key().contains("-FSL_AN-h1-"), "{}", an.key());
    let an_rec = h.run_cached(&an).unwrap();
    assert_ne!(
        run_to_json(&novel).pretty(),
        run_to_json(&an_rec).pretty(),
        "the period must change results"
    );
    let cse = RunSpec { method: Method::CseFsl.spec().with_period(2), ..base.clone() };
    assert!(cse.key().contains("-CSE_FSL-h2-"), "{}", cse.key());
    let cse_rec = h.run_cached(&cse).unwrap();
    assert_ne!(
        run_to_json(&novel).pretty(),
        run_to_json(&cse_rec).pretty(),
        "the topology must change results"
    );
    // Axis separation, exactly: the topology axis moves *storage only*.
    // Wire bytes and the simulated schedule are value-independent, so
    // the per-client arm and its shared control at the same h match
    // them bit-for-bit while training different models.
    assert_eq!(novel.total_up_bytes, cse_rec.total_up_bytes, "topology must not move bytes");
    assert_eq!(novel.total_down_bytes, cse_rec.total_down_bytes);
    assert_eq!(novel.sim_time, cse_rec.sim_time, "topology must not move the schedule");
    // Storage follows the topology axis: per-client pays n copies.
    assert!(
        novel.server_storage_params > cse_rec.server_storage_params,
        "per-client topology must store more than shared ({} vs {})",
        novel.server_storage_params,
        cse_rec.server_storage_params
    );
    // Incoherent specs fail before the cache is touched.
    let bad = RunSpec { method: Method::FslMc.spec().with_period(2), ..base };
    assert!(h.run_cached(&bad).unwrap_err().contains("server-grad"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compression_axis_keeps_preset_keys_and_gets_canonical_tags() {
    // Cache back-compat is a hard acceptance criterion of the
    // compression axis: `Compression::None` — explicit or defaulted —
    // must leave every preset key string byte-identical to its pre-axis
    // literal, while any lossy codec demotes the spec to a canonical
    // tagged key that can never collide with a preset entry.
    let base = |method: MethodSpec| RunSpec {
        dataset: "cifar".into(),
        aux: "cnn27".into(),
        method,
        n_clients: 5,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: 0.05,
        seed: 1,
        workload: cifar_workload(Scale::Quick),
        parallelism: Parallelism::Sequential,
        server_shards: 1,
        sched: SchedPolicy::RoundRobin,
        shard_map: ShardMapKind::Contiguous,
    };
    let tail = "n5-p0-iid-delay-lr0.05-r4-d100-t100-k1-mcont-s1";
    for (method, name) in [
        (Method::FslMc, "FSL_MC"),
        (Method::FslOc, "FSL_OC"),
        (Method::FslAn, "FSL_AN"),
        (Method::CseFsl, "CSE_FSL"),
    ] {
        let expected = format!("cifar-cnn27-{name}-h1-{tail}");
        assert_eq!(base(method.spec()).key(), expected, "{method} defaulted axis");
        assert_eq!(
            base(method.spec().with_compression(Compression::None)).key(),
            expected,
            "{method} explicit Compression::None"
        );
    }
    // Lossy codecs join the method segment with canonical tags.
    let q4 = base(
        Method::CseFsl.spec().with_period(2).with_compression(Compression::Quantize {
            bits: 4,
        }),
    );
    assert_eq!(q4.key(), format!("cifar-cnn27-aux+p2+sh+q4-h2-{tail}"));
    assert_eq!(q4.label(), "aux+p2+sh+q4");
    let topk = base(
        Method::FslAn.spec().with_compression(Compression::TopK { frac: 0.25 }),
    );
    assert_eq!(topk.key(), format!("cifar-cnn27-aux+b+pc+t0.25-h1-{tail}"));
    // Distinct codec points never share a key.
    let q8 = base(
        Method::CseFsl.spec().with_period(2).with_compression(Compression::Quantize {
            bits: 8,
        }),
    );
    assert_ne!(q4.key(), q8.key());
}

#[test]
fn v2_cache_records_written_before_the_compression_axis_still_replay() {
    // A cache entry written by the pre-axis binary (schema v2, preset
    // key) must replay verbatim under the new binary: same key string,
    // same JSON schema, no re-run. The record below is hand-written to
    // the v2 schema — if `run_cached` ever re-ran the spec, the label
    // and numbers could not survive.
    let dir = std::env::temp_dir().join(format!(
        "cse_fsl_spec_eq_{}_{}",
        std::process::id(),
        line!()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let mut wl = femnist_workload(Scale::Quick);
    wl.rounds = 4;
    let spec = RunSpec {
        dataset: "femnist".into(),
        aux: "cnn8".into(),
        method: Method::CseFsl.spec().with_period(2),
        n_clients: 4,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: 0.05,
        seed: 1,
        workload: wl,
        parallelism: Parallelism::Sequential,
        server_shards: 1,
        sched: SchedPolicy::RoundRobin,
        shard_map: ShardMapKind::Contiguous,
    };
    // The preset key is the pre-axis literal (pinned end to end).
    assert_eq!(
        spec.key(),
        "femnist-cnn8-CSE_FSL-h2-n4-p0-iid-delay-lr0.05-r4-d60-t120-k1-mcont-s1"
    );
    let prerecorded = r#"{
  "cache_version": 2,
  "label": "prerecorded v2",
  "rounds": [
    {
      "round": 1,
      "sim_time": 0.5,
      "lr": 0.05,
      "train_loss": 1.25,
      "server_loss": 1.5,
      "up_bytes": 1024,
      "down_bytes": 2048,
      "accuracy": null,
      "client_grad_norm": null,
      "server_grad_norm": null
    }
  ],
  "final_accuracy": 0.75,
  "total_up_bytes": 1024,
  "total_down_bytes": 2048,
  "sim_time": 0.5,
  "server_idle_fraction": 0.25,
  "server_storage_params": 64,
  "shard_label_divergence": 0.0,
  "clients_activated": 4
}"#;
    let cache = dir.join("cache").join("mock").join(format!("{}.json", spec.key()));
    std::fs::write(&cache, prerecorded).unwrap();
    let rec = h.run_cached(&spec).unwrap();
    assert_eq!(rec.label, "prerecorded v2", "the cache entry must replay, not re-run");
    assert_eq!(rec.rounds.len(), 1);
    assert_eq!(rec.final_accuracy, 0.75);
    assert_eq!(rec.total_up_bytes, 1024);
    assert_eq!(rec.clients_activated, 4);
    // A compressed spec at the same point does NOT hit that entry — it
    // lives under its own tagged key, so it runs (rounds == workload).
    let compressed = RunSpec {
        method: Method::CseFsl
            .spec()
            .with_period(2)
            .with_compression(Compression::Quantize { bits: 4 }),
        ..spec
    };
    assert!(compressed.key().contains("-aux+p2+sh+q4-h2-"), "{}", compressed.key());
    let crec = h.run_cached(&compressed).unwrap();
    assert_eq!(crec.rounds.len(), 4);
    assert_ne!(crec.label, "prerecorded v2");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sage_axis_keeps_preset_keys_and_round_trips_its_canonical_tag() {
    // Back-compat pin for the sage axis: the four paper preset key
    // strings stay byte-identical to their pre-sage literals — no sage
    // segment can ever leak into them — while a sage point gets the
    // canonical `sage{a}+p{h}+sh` tagged key.
    let base = |method: MethodSpec| RunSpec {
        dataset: "cifar".into(),
        aux: "cnn27".into(),
        method,
        n_clients: 5,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: 0.05,
        seed: 1,
        workload: cifar_workload(Scale::Quick),
        parallelism: Parallelism::Sequential,
        server_shards: 1,
        sched: SchedPolicy::RoundRobin,
        shard_map: ShardMapKind::Contiguous,
    };
    let tail = "n5-p0-iid-delay-lr0.05-r4-d100-t100-k1-mcont-s1";
    for (method, name) in [
        (Method::FslMc, "FSL_MC"),
        (Method::FslOc, "FSL_OC"),
        (Method::FslAn, "FSL_AN"),
        (Method::CseFsl, "CSE_FSL"),
    ] {
        let key = base(method.spec()).key();
        assert_eq!(key, format!("cifar-cnn27-{name}-h1-{tail}"), "{method} preset key");
        assert!(!key.contains("sage"), "{method}: sage segment leaked into {key}");
    }
    // The canonical sage tag joins the method segment of the key; the
    // clip forks it (results change with the clip, so the key must).
    let sage = |a: usize, clip: f32| {
        base(MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: a, clip },
            ..Method::CseFsl.spec().with_period(2)
        })
    };
    assert_eq!(sage(3, 0.0).key(), format!("cifar-cnn27-sage3+p2+sh-h2-{tail}"));
    assert_eq!(sage(3, 0.0).label(), "sage3+p2+sh");
    assert_eq!(sage(3, 0.5).key(), format!("cifar-cnn27-sage3c0.5+p2+sh-h2-{tail}"));
    assert_ne!(sage(3, 0.0).key(), sage(4, 0.0).key(), "the period must fork the key");
    // And the codec composes on top, like every other axis point.
    let compressed = base(MethodSpec {
        update: ClientUpdate::SageEstimate { align_every: 3, clip: 0.0 },
        ..Method::CseFsl
            .spec()
            .with_period(2)
            .with_compression(Compression::Quantize { bits: 4 })
    });
    assert_eq!(compressed.key(), format!("cifar-cnn27-sage3+p2+sh+q4-h2-{tail}"));
}

#[test]
fn sage_sibling_misses_the_v2_preset_cache_entry_and_reruns() {
    // A v2 cache record written under the CSE_FSL preset key must keep
    // replaying for the preset — and the sage point at the very same
    // axes must MISS it (its key carries the sage segment), run live,
    // and land in its own cache entry that then replays bitwise.
    let dir = std::env::temp_dir().join(format!(
        "cse_fsl_spec_eq_{}_{}",
        std::process::id(),
        line!()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let mut wl = femnist_workload(Scale::Quick);
    wl.rounds = 4;
    let preset = RunSpec {
        dataset: "femnist".into(),
        aux: "cnn8".into(),
        method: Method::CseFsl.spec().with_period(2),
        n_clients: 4,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: 0.05,
        seed: 1,
        workload: wl,
        parallelism: Parallelism::Sequential,
        server_shards: 1,
        sched: SchedPolicy::RoundRobin,
        shard_map: ShardMapKind::Contiguous,
    };
    let prerecorded = r#"{
  "cache_version": 2,
  "label": "prerecorded v2",
  "rounds": [
    {
      "round": 1,
      "sim_time": 0.5,
      "lr": 0.05,
      "train_loss": 1.25,
      "server_loss": 1.5,
      "up_bytes": 1024,
      "down_bytes": 2048,
      "accuracy": null,
      "client_grad_norm": null,
      "server_grad_norm": null
    }
  ],
  "final_accuracy": 0.75,
  "total_up_bytes": 1024,
  "total_down_bytes": 2048,
  "sim_time": 0.5,
  "server_idle_fraction": 0.25,
  "server_storage_params": 64,
  "shard_label_divergence": 0.0,
  "clients_activated": 4
}"#;
    let cache = dir.join("cache").join("mock").join(format!("{}.json", preset.key()));
    std::fs::write(&cache, prerecorded).unwrap();
    let sage = RunSpec {
        method: MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: 2, clip: 0.0 },
            ..Method::CseFsl.spec().with_period(2)
        },
        ..preset.clone()
    };
    assert!(sage.validate().is_ok());
    assert!(sage.key().contains("-sage2+p2+sh-h2-"), "{}", sage.key());
    // The sage sibling runs live (4 workload rounds, its own label)...
    let srec = h.run_cached(&sage).unwrap();
    assert_eq!(srec.rounds.len(), 4, "sage must re-run, not replay the preset entry");
    assert_eq!(srec.label, "sage2+p2+sh");
    // ...lands under its own key...
    let sage_cache =
        dir.join("cache").join("mock").join(format!("{}.json", sage.key()));
    assert!(sage_cache.is_file(), "missing {}", sage_cache.display());
    // ...and replays bitwise from there.
    let replay = h.run_cached(&sage).unwrap();
    assert_eq!(run_to_json(&srec).pretty(), run_to_json(&replay).pretty());
    // The preset entry stayed untouched and still replays.
    let prec = h.run_cached(&preset).unwrap();
    assert_eq!(prec.label, "prerecorded v2", "preset cache entry must survive");
    assert_eq!(prec.rounds.len(), 1);
    // The alignment downlink is live in the sage run: downlink bytes
    // exceed the aux-local sibling's at the same axes.
    let aux = RunSpec { method: Method::CseFsl.spec().with_period(2), seed: 2, ..preset };
    let arec = h.run_cached(&aux).unwrap();
    // Byte totals are value-independent, so the seed difference cannot
    // move them: uplinks match exactly, and the sage downlink exceeds
    // the aux-local one by exactly the alignment records.
    assert_eq!(srec.total_up_bytes, arec.total_up_bytes, "uplink must not move");
    assert!(
        srec.total_down_bytes > arec.total_down_bytes,
        "alignment downlinks missing ({} <= {})",
        srec.total_down_bytes,
        arec.total_down_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);
}
