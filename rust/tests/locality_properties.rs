//! Locality shard-map invariants (util/prop harness).
//!
//! 1. **Permutation invariance** — permuting the client inputs
//!    (histograms + costs) permutes the grouping with them: the induced
//!    partition of the *original* clients is identical up to shard
//!    relabeling (client ids only break ties between data-identical
//!    clients).
//! 2. **Coverage + balance** — every client lands in exactly one shard,
//!    no shard is empty, shard client counts differ by at most one, and
//!    per-shard cost stays within one item of the greedy
//!    list-scheduling bound.
//! 3. **One-hot optimality** — in the α → 0 limit of the Dirichlet
//!    protocol (every client holds a single label, equal sample
//!    counts, uniform costs, k | n) the wave dealing provably minimizes
//!    the shard-skew metric: per (shard, label) counts are the balanced
//!    ⌊m/k⌋/⌈m/k⌉ allocation, so no equal-size grouping — contiguous
//!    and cost-balanced included — can score lower. Checked per case.
//! 4. **Dirichlet(α = 0.1) splits** — over fixed real `dirichlet`
//!    partitions (harsher skew than the α = 0.3 the CIFAR figure arm
//!    runs) the locality map's skew is lower than the contiguous
//!    and balanced maps' *on average*, with a solid pointwise win rate
//!    (pointwise ≤ on arbitrary mixed histograms is not a theorem — a
//!    lucky id ordering can hand contiguous a near-optimal grouping —
//!    which is exactly why the per-case guarantee is stated and checked
//!    in the one-hot limit above).

use cse_fsl::coordinator::server::ShardMap;
use cse_fsl::data::partition::dirichlet;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::prop_assert;
use cse_fsl::sched;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

/// Shard cohorts as a canonical set-of-sets (sorted members, sorted
/// groups, empties dropped) — the "up to relabeling" comparison form.
fn canon(groups: Vec<Vec<usize>>) -> Vec<Vec<usize>> {
    let mut g: Vec<Vec<usize>> = groups
        .into_iter()
        .map(|mut v| {
            v.sort_unstable();
            v
        })
        .filter(|v| !v.is_empty())
        .collect();
    g.sort();
    g
}

/// Random label-skewed histograms: every client gets a dominant label
/// plus light noise on the others (a Dirichlet-small-α caricature).
fn skewed_hists(rng: &mut Rng, n: usize, classes: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|_| {
            let mut h = vec![0usize; classes];
            for v in h.iter_mut() {
                *v = rng.below(4) as usize;
            }
            let dom = rng.below(classes as u64) as usize;
            h[dom] += 30 + rng.below(20) as usize;
            h
        })
        .collect()
}

#[test]
fn prop_locality_permutation_invariant_up_to_relabeling() {
    prop::check("locality invariant to client permutation", |rng| {
        let n = 2 + rng.below(10) as usize; // 2..=11 clients
        let k = 2 + rng.below(n as u64 - 1) as usize; // 2..=n shards
        let classes = 2 + rng.below(5) as usize;
        let hists = skewed_hists(rng, n, classes);
        // Continuous costs: ties between distinct clients have measure
        // zero, so the id tie-break never decides between them.
        let costs: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 10.0)).collect();
        let m1 = ShardMap::locality(n, k, &hists, &costs);
        let perm = rng.permutation(n);
        let ph: Vec<Vec<usize>> = (0..n).map(|i| hists[perm[i]].clone()).collect();
        let pc: Vec<f64> = (0..n).map(|i| costs[perm[i]]).collect();
        let m2 = ShardMap::locality(n, k, &ph, &pc);
        // Map the permuted grouping back to original client ids.
        let g1 = canon((0..k).map(|s| m1.clients_of(s)).collect());
        let g2 = canon(
            (0..k)
                .map(|s| m2.clients_of(s).iter().map(|&i| perm[i]).collect())
                .collect(),
        );
        prop_assert!(
            g1 == g2,
            "groupings diverged under permutation (n={n} k={k}): {g1:?} vs {g2:?}"
        );
        let d1 = m1.label_divergence(&hists);
        let d2 = m2.label_divergence(&ph);
        prop_assert!((d1 - d2).abs() < 1e-9, "divergence diverged: {d1} vs {d2}");
        Ok(())
    });
}

#[test]
fn prop_locality_covers_balances_and_bounds_cost() {
    prop::check("locality coverage + count balance + cost bound", |rng| {
        let n = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(n as u64) as usize;
        let classes = 1 + rng.below(6) as usize;
        let hists = skewed_hists(rng, n, classes);
        let costs: Vec<f64> = (0..n).map(|_| rng.lognormal(1.0, 1.2)).collect();
        let map = ShardMap::locality(n, k, &hists, &costs);
        prop_assert!(map.shards() == k, "shard count {} != {k}", map.shards());
        // Permutation of the clients: everyone exactly once, no shard
        // empty, counts within one of each other.
        let mut seen: Vec<usize> = (0..k).flat_map(|s| map.clients_of(s)).collect();
        seen.sort_unstable();
        prop_assert!(seen == (0..n).collect::<Vec<_>>(), "not a partition: {seen:?}");
        let counts: Vec<usize> = (0..k).map(|s| map.clients_of(s).len()).collect();
        prop_assert!(counts.iter().all(|&c| c > 0), "empty shard (n={n} k={k})");
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        prop_assert!(max - min <= 1, "unbalanced counts {counts:?}");
        // Cost balance: the wave dealing is cost-greedy under a
        // one-per-shard-per-wave restriction, so allow the greedy bound
        // plus one item of slack.
        let load = |s: usize| map.clients_of(s).iter().map(|&c| costs[c]).sum::<f64>();
        let max_load = (0..k).map(load).fold(0.0f64, f64::max);
        let cmax = costs.iter().copied().fold(0.0f64, f64::max);
        let bound = sched::greedy_bound(&costs, k) + cmax;
        prop_assert!(
            max_load <= bound + 1e-9,
            "max load {max_load} exceeds bound {bound} (n={n} k={k})"
        );
        // The skew metric is always a valid mean TV distance.
        let d = map.label_divergence(&hists);
        prop_assert!((0.0..=1.0).contains(&d), "divergence {d} out of range");
        Ok(())
    });
}

#[test]
fn prop_locality_minimizes_skew_in_one_hot_limit() {
    prop::check("locality optimal for one-hot clients", |rng| {
        let k = 2 + rng.below(3) as usize; // 2..=4 shards
        let n = k * (1 + rng.below(6) as usize); // k | n, n <= 24
        let classes = 2 + rng.below(4) as usize;
        let w = 10usize;
        let hists: Vec<Vec<usize>> = (0..n)
            .map(|_| {
                let mut h = vec![0usize; classes];
                h[rng.below(classes as u64) as usize] = w;
                h
            })
            .collect();
        let costs = vec![1.0; n];
        let loc = ShardMap::locality(n, k, &hists, &costs).label_divergence(&hists);
        let cont = ShardMap::contiguous(n, k).label_divergence(&hists);
        let bal = ShardMap::balanced(n, k, &costs).label_divergence(&hists);
        // Equal-mass one-hot clients, uniform costs, k | n: the wave
        // dealing hands every (shard, label) pair the balanced
        // ⌊m/k⌋/⌈m/k⌉ client count, which minimizes the mean per-shard
        // TV distance over ALL equal-size groupings — contiguous and
        // LPT included.
        prop_assert!(loc <= cont + 1e-9, "one-hot: locality {loc} > contiguous {cont}");
        prop_assert!(loc <= bal + 1e-9, "one-hot: locality {loc} > balanced {bal}");
        Ok(())
    });
}

#[test]
fn locality_stratifies_dirichlet_splits_on_average() {
    // Real Dirichlet(α = 0.1) splits — the FedLite benchmark protocol
    // at harsher skew than the shipped CIFAR figure arm (which runs
    // α = 0.3 in `Harness::data`) — fixed seeds → fully deterministic
    // outcome. Across 64
    // splits × k ∈ {2, 4}: the locality map's mean skew is strictly
    // below the contiguous and cost-only balanced maps', and it wins
    // pointwise against contiguous in well over half the cases (the
    // pointwise guarantee itself lives in the one-hot property above).
    let spec = SyntheticSpec {
        height: 2,
        width: 2,
        channels: 2,
        classes: 3,
        ..SyntheticSpec::cifar_like()
    };
    let n = 8usize;
    let mut sums = (0.0f64, 0.0f64, 0.0f64); // (locality, contiguous, balanced)
    let mut cases = 0usize;
    let mut wins_vs_cont = 0usize;
    for seed in 0..64u64 {
        let ds = generate(&spec, 400, 1000 + seed);
        let mut rng = Rng::new(seed);
        let part = dirichlet(&ds, n, 0.1, &mut rng);
        let hists = part.label_histograms(&ds);
        let costs: Vec<f64> = (0..n).map(|_| rng.lognormal(1.0, 0.8)).collect();
        for k in [2usize, 4] {
            let loc = ShardMap::locality(n, k, &hists, &costs).label_divergence(&hists);
            let cont = ShardMap::contiguous(n, k).label_divergence(&hists);
            let bal = ShardMap::balanced(n, k, &costs).label_divergence(&hists);
            sums.0 += loc;
            sums.1 += cont;
            sums.2 += bal;
            cases += 1;
            if loc <= cont + 1e-12 {
                wins_vs_cont += 1;
            }
        }
    }
    let (ml, mc, mb) =
        (sums.0 / cases as f64, sums.1 / cases as f64, sums.2 / cases as f64);
    assert!(ml < mc, "mean skew: locality {ml} !< contiguous {mc}");
    assert!(ml < mb, "mean skew: locality {ml} !< balanced {mb}");
    assert!(
        wins_vs_cont * 2 > cases,
        "locality won only {wins_vs_cont}/{cases} splits vs contiguous"
    );
}
