//! Coordinator behaviour over the mock engine: method semantics, wire
//! accounting vs closed forms, aggregation, determinism, participation,
//! and the Fig.-6 order-invariance claim — all in milliseconds, no PJRT.

use cse_fsl::comm::accounting::{table2, MsgKind, WireSizes};
use cse_fsl::coordinator::config::{ArrivalOrder, TrainConfig};
use cse_fsl::coordinator::methods::{ClientUpdate, Method};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::model::aggregate::max_abs_diff;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn setup<'a>(
    train: &'a Dataset,
    test: &'a Dataset,
    n_clients: usize,
    label: &str,
) -> TrainerSetup<'a> {
    let mut rng = Rng::new(7);
    TrainerSetup {
        train,
        test,
        partition: iid(train, n_clients, &mut rng),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: label.to_string(),
    }
}

fn engine() -> MockEngine {
    // batch=4, classes=3, input_len=8 matches spec() (2*2*2)
    MockEngine::small(42)
}

#[test]
fn all_methods_run_and_losses_fall() {
    let train = dataset(64, 1);
    let test = dataset(32, 2);
    for method in Method::ALL {
        let e = engine();
        let cfg = TrainConfig { lr0: 2.0, ..TrainConfig::new(method) }.with_rounds(30);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, "t")).unwrap();
        let rec = tr.run().unwrap();
        assert_eq!(rec.rounds.len(), 30, "{method}");
        let first = rec.rounds[0].train_loss;
        let last = rec.rounds[29].train_loss;
        assert!(last < first, "{method}: loss {first} -> {last}");
        assert!(rec.final_accuracy >= 0.0 && rec.final_accuracy <= 1.0);
        assert!(rec.sim_time > 0.0);
    }
}

#[test]
fn server_copy_counts_match_method() {
    let train = dataset(64, 1);
    let test = dataset(16, 2);
    for (method, copies) in
        [(Method::FslMc, 5), (Method::FslOc, 1), (Method::FslAn, 5), (Method::CseFsl, 1)]
    {
        let e = engine();
        let cfg = TrainConfig::new(method).with_rounds(2);
        let tr = Trainer::new(&e, cfg, setup(&train, &test, 5, "t")).unwrap();
        assert_eq!(tr.server.copies.len(), copies, "{method}");
        assert_eq!(tr.server.resident_params(), copies * e.server_size());
    }
}

#[test]
fn grad_downlink_only_for_splitfed_methods() {
    let train = dataset(64, 1);
    let test = dataset(16, 2);
    for method in Method::ALL {
        let e = engine();
        let cfg = TrainConfig { agg_every: 3, ..TrainConfig::new(method) }.with_rounds(6);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).unwrap();
        tr.run().unwrap();
        let grad_bytes = tr.ledger.bytes_of(MsgKind::GradDownload);
        let aux_bytes = tr.ledger.bytes_of(MsgKind::AuxModelUpload);
        // The update axis alone decides both wire behaviors.
        match method.spec().update {
            ClientUpdate::ServerGrad { .. } => {
                assert!(grad_bytes > 0, "{method} should downlink grads");
                assert_eq!(aux_bytes, 0, "{method} must not upload aux nets");
            }
            ClientUpdate::AuxLocal => {
                assert_eq!(grad_bytes, 0, "{method} must not downlink grads");
                assert!(aux_bytes > 0, "{method} should upload aux nets");
            }
        }
    }
}

#[test]
fn measured_bytes_match_table2_closed_form() {
    // Run exactly one "epoch": each of n clients walks its |D_i| samples
    // once with one aggregation — the unit Table II counts.
    let n = 4usize;
    let per_client = 16usize; // |D_i|
    let train = dataset(n * per_client, 3);
    let test = dataset(16, 4);
    let e = engine();
    let batches_per_epoch = per_client / e.batch; // 4
    let w = WireSizes::new(e.smashed_len, e.client_size(), e.aux_size());

    // CSE_FSL with h=2: rounds per epoch = batches/h = 2, aggregate at
    // the end of the epoch.
    let h = 2usize;
    let rounds = batches_per_epoch / h;
    let cfg = TrainConfig {
        rounds,
        agg_every: rounds,
        eval_every: 0,
        ..TrainConfig::new(Method::CseFsl).with_h(h)
    };
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, n, "t")).unwrap();
    tr.run().unwrap();
    let measured = tr.ledger.total_bytes();
    let predicted = table2::cse_fsl(n as u64, per_client as u64, h as u64, &w);
    assert_eq!(measured, predicted, "CSE_FSL_h accounting");

    // FSL_MC one epoch: rounds = batches_per_epoch.
    let e2 = engine();
    let cfg = TrainConfig {
        rounds: batches_per_epoch,
        agg_every: batches_per_epoch,
        eval_every: 0,
        ..TrainConfig::new(Method::FslMc)
    };
    let mut tr = Trainer::new(&e2, cfg, setup(&train, &test, n, "t")).unwrap();
    tr.run().unwrap();
    assert_eq!(
        tr.ledger.total_bytes(),
        table2::fsl_mc(n as u64, per_client as u64, &w),
        "FSL_MC accounting"
    );

    // FSL_AN one epoch.
    let e3 = engine();
    let cfg = TrainConfig {
        rounds: batches_per_epoch,
        agg_every: batches_per_epoch,
        eval_every: 0,
        ..TrainConfig::new(Method::FslAn)
    };
    let mut tr = Trainer::new(&e3, cfg, setup(&train, &test, n, "t")).unwrap();
    tr.run().unwrap();
    assert_eq!(
        tr.ledger.total_bytes(),
        table2::fsl_an(n as u64, per_client as u64, &w),
        "FSL_AN accounting"
    );
}

#[test]
fn larger_h_uploads_fewer_smashed_bytes_per_batchwork() {
    let train = dataset(96, 5);
    let test = dataset(16, 6);
    let mut totals = Vec::new();
    for h in [1usize, 2, 4] {
        let e = engine();
        // same total local batches (8) for every h
        let rounds = 8 / h;
        let cfg = TrainConfig {
            rounds,
            agg_every: rounds,
            eval_every: 0,
            ..TrainConfig::new(Method::CseFsl).with_h(h)
        };
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).unwrap();
        tr.run().unwrap();
        totals.push(tr.ledger.bytes_of(MsgKind::SmashedUpload));
    }
    assert_eq!(totals[0], 2 * totals[1]);
    assert_eq!(totals[0], 4 * totals[2]);
}

#[test]
fn aggregation_synchronizes_clients() {
    let train = dataset(64, 7);
    let test = dataset(16, 8);
    let e = engine();
    let cfg = TrainConfig { agg_every: 5, ..TrainConfig::new(Method::CseFsl) }.with_rounds(5);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, "t")).unwrap();
    tr.run().unwrap();
    // last round was an aggregation round: all clients share xc
    for c in &tr.clients[1..] {
        assert_eq!(c.xc, tr.clients[0].xc);
        assert_eq!(c.ac, tr.clients[0].ac);
    }
}

#[test]
fn between_aggregations_clients_diverge() {
    let train = dataset(64, 9);
    let test = dataset(16, 10);
    let e = engine();
    // aggregation far beyond the horizon
    let cfg = TrainConfig { agg_every: 100, lr0: 1.0, ..TrainConfig::new(Method::CseFsl) }
        .with_rounds(4);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).unwrap();
    tr.run().unwrap();
    // mock dynamics pull everyone to the same target, but trajectories
    // (different batches/seeds) must not be bitwise identical
    assert!(max_abs_diff(&tr.clients[0].xc, &tr.clients[1].xc) > 0.0);
}

#[test]
fn deterministic_given_seed() {
    let train = dataset(64, 11);
    let test = dataset(16, 12);
    let run = |seed: u64| {
        let e = engine();
        let cfg = TrainConfig::new(Method::CseFsl).with_h(2).with_rounds(10).with_seed(seed);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).unwrap();
        let rec = tr.run().unwrap();
        (rec.final_accuracy, rec.total_up_bytes, tr.clients[0].xc.clone(), rec.sim_time)
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a.0, b.0);
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert_eq!(a.3, b.3);
    let c = run(6);
    assert!(a.2 != c.2 || a.3 != c.3, "different seeds should differ somewhere");
}

#[test]
fn partial_participation_limits_round_traffic() {
    let train = dataset(120, 13);
    let test = dataset(16, 14);
    let e = engine();
    let cfg = TrainConfig {
        participation: 2,
        agg_every: 1000,
        eval_every: 0,
        ..TrainConfig::new(Method::CseFsl)
    }
    .with_rounds(1);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 6, "t")).unwrap();
    tr.run().unwrap();
    // exactly 2 smashed uploads happened
    assert_eq!(tr.ledger.count_of(MsgKind::SmashedUpload), 2);
}

#[test]
fn fig6_order_invariance_holds_in_spirit() {
    // Same seed, same everything, only the server's consumption order of
    // arrivals differs: trajectories must stay close (the paper's Fig. 6
    // claim) while not being bitwise identical.
    let train = dataset(64, 15);
    let test = dataset(32, 16);
    let run = |arrival: ArrivalOrder| {
        let e = engine();
        let cfg = TrainConfig {
            arrival,
            lr0: 1.0,
            ..TrainConfig::new(Method::CseFsl)
        }
        .with_rounds(20);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, "t")).unwrap();
        let rec = tr.run().unwrap();
        (tr.server.copies[0].clone(), rec.final_accuracy)
    };
    let (xs_ordered, acc_ordered) = run(ArrivalOrder::ClientIndex);
    let (xs_shuffled, acc_shuffled) = run(ArrivalOrder::Shuffled);
    let diff = max_abs_diff(&xs_ordered, &xs_shuffled);
    assert!(diff < 0.05, "order changed the model too much: {diff}");
    assert!((acc_ordered - acc_shuffled).abs() < 0.2);
}

#[test]
fn server_updates_counted_per_upload() {
    let train = dataset(64, 17);
    let test = dataset(16, 18);
    let e = engine();
    let rounds = 7usize;
    let n = 3usize;
    let cfg = TrainConfig { eval_every: 0, agg_every: 1000, ..TrainConfig::new(Method::CseFsl) }
        .with_rounds(rounds);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, n, "t")).unwrap();
    tr.run().unwrap();
    assert_eq!(tr.server.updates, (rounds * n) as u64);
}

#[test]
fn timeline_records_server_activity_and_idle() {
    let train = dataset(64, 19);
    let test = dataset(16, 20);
    let e = engine();
    let cfg = TrainConfig::new(Method::CseFsl).with_rounds(5);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, "t")).unwrap();
    let rec = tr.run().unwrap();
    assert!(tr.timeline.server_busy() > 0.0);
    assert!(rec.server_idle_fraction > 0.0 && rec.server_idle_fraction < 1.0);
    // clients actually interleave: straggler spread is positive under
    // heterogeneous profiles
    assert!(tr.timeline.straggler_spread() > 0.0);
}

#[test]
fn rejects_invalid_configs() {
    let train = dataset(64, 21);
    let test = dataset(16, 22);
    let e = engine();
    let cfg = TrainConfig::new(Method::FslMc).with_h(4);
    assert!(Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).is_err());
    let cfg = TrainConfig { participation: 10, ..TrainConfig::new(Method::CseFsl) };
    assert!(Trainer::new(&e, cfg, setup(&train, &test, 3, "t")).is_err());
}
