//! Data-substrate integrity: the properties the experiments silently rely
//! on — shared glyph alphabets across train/test, disjoint writers,
//! deterministic regeneration, and partition invariants under the
//! property harness.

use cse_fsl::data::femnist::{self, FemnistSpec};
use cse_fsl::data::partition;
use cse_fsl::data::synthetic::{train_test as syn_train_test, SyntheticSpec};
use cse_fsl::prop_assert;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> FemnistSpec {
    FemnistSpec { writers: 8, samples_per_writer: 12, ..FemnistSpec::default_like() }
}

#[test]
fn femnist_train_test_share_glyph_alphabet() {
    // Same class => correlated mean images across train and test (the
    // test split must be *learnable*: it was not, before train_test()).
    let big = FemnistSpec { writers: 40, samples_per_writer: 30, ..FemnistSpec::default_like() };
    let (train, test) = femnist::train_test(&big, 40, 3);
    let side = 28 * 28;
    let mean_img = |ds: &cse_fsl::data::Dataset, class: i32| -> Option<Vec<f32>> {
        let idx: Vec<usize> =
            (0..ds.len()).filter(|&i| ds.labels[i] == class).collect();
        if idx.len() < 4 {
            return None;
        }
        let mut m = vec![0f32; side];
        for &i in &idx {
            for (a, b) in m.iter_mut().zip(ds.image(i)) {
                *a += b / idx.len() as f32;
            }
        }
        Some(m)
    };
    let corr = |a: &[f32], b: &[f32]| -> f32 {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        dot / (na * nb).max(1e-9)
    };
    let mut matched = 0;
    let mut checked = 0;
    for class in 0..62 {
        let (Some(tr), Some(te)) = (mean_img(&train, class), mean_img(&test, class)) else {
            continue;
        };
        checked += 1;
        if corr(&tr, &te) > 0.5 {
            matched += 1;
        }
    }
    assert!(checked >= 5, "not enough shared classes to check ({checked})");
    assert!(
        matched * 10 >= checked * 7,
        "train/test glyphs disagree: {matched}/{checked} correlated"
    );
}

#[test]
fn femnist_train_test_use_disjoint_writer_styles() {
    let (train, test) = femnist::train_test(&spec(), 8, 3);
    // Styles are drawn from disjoint RNG streams; images of the same
    // class should still differ between splits (not bitwise shared).
    assert_ne!(train.images[..784], test.images[..784]);
    assert_eq!(train.classes, test.classes);
}

#[test]
fn femnist_iid_train_test_learnable_pair() {
    let (train, test) = femnist::train_test_iid(&spec(), 96, 9);
    assert_eq!(train.shape, test.shape);
    assert!(test.len() >= 90);
    // IID: labels roughly uniform
    let hist = train.class_histogram();
    let top = *hist.iter().max().unwrap() as f64 / train.len() as f64;
    assert!(top < 0.15, "{top}");
}

#[test]
fn synthetic_train_test_same_templates() {
    let spec = SyntheticSpec { height: 8, width: 8, channels: 1, classes: 4, ..SyntheticSpec::cifar_like() };
    let (a_train, a_test) = syn_train_test(&spec, 16, 16, 5);
    let (b_train, _) = syn_train_test(&spec, 16, 16, 5);
    assert_eq!(a_train.images, b_train.images, "regeneration must be exact");
    assert_ne!(a_train.images, a_test.images);
}

#[test]
fn prop_partitions_are_disjoint_and_complete() {
    prop::check("dirichlet partition validity", |rng| {
        let n = 20 + rng.below(200) as usize;
        let k = 2 + rng.below(6) as usize;
        let alpha = 0.1 + rng.uniform() * 5.0;
        let spec = SyntheticSpec { height: 2, width: 2, channels: 1, classes: 5, ..SyntheticSpec::cifar_like() };
        let ds = cse_fsl::data::synthetic::generate(&spec, n, rng.next_u64());
        let p = partition::dirichlet(&ds, k, alpha, rng);
        p.validate(ds.len()).map_err(|e| e)?;
        prop_assert!(p.total() == ds.len(), "dirichlet dropped samples: {} != {n}", p.total());
        Ok(())
    });
}

#[test]
fn prop_equalized_partitions_are_uniform() {
    prop::check("equalize uniformity", |rng| {
        let n = 50 + rng.below(150) as usize;
        let k = 2 + rng.below(5) as usize;
        let spec = SyntheticSpec { height: 2, width: 2, channels: 1, classes: 3, ..SyntheticSpec::cifar_like() };
        let ds = cse_fsl::data::synthetic::generate(&spec, n, rng.next_u64());
        let mut p = partition::dirichlet(&ds, k, 0.3, rng);
        partition::equalize(&mut p);
        let len0 = p.clients[0].len();
        prop_assert!(
            p.clients.iter().all(|c| c.len() == len0),
            "equalize left unequal shards"
        );
        p.validate(ds.len()).map_err(|e| e)?;
        Ok(())
    });
}

#[test]
fn prop_batcher_never_repeats_within_epoch() {
    prop::check("batcher epoch coverage", |rng| {
        let shard_n = 4 + rng.below(60) as usize;
        let bs = 1 + rng.below(8) as usize;
        let mut b = cse_fsl::data::batcher::Batcher::new(
            (0..shard_n).collect(),
            bs,
            Rng::new(rng.next_u64()),
        );
        // over exactly LCM-ish horizon: count occurrences in k*shard_n draws
        let batches = 3 * shard_n; // 3 epochs worth of samples per item
        let mut counts = vec![0usize; shard_n];
        let mut buf = Vec::new();
        for _ in 0..batches {
            b.next_batch(&mut buf);
            for &i in &buf {
                counts[i] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        prop_assert!(total == batches * bs, "lost samples");
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        prop_assert!(max - min <= 1, "unfair batcher: min {min} max {max}");
        Ok(())
    });
}

#[test]
fn prop_event_queue_is_time_ordered() {
    prop::check("event queue ordering", |rng| {
        let mut q = cse_fsl::sim::event::EventQueue::new();
        let n = 1 + rng.below(200) as usize;
        for i in 0..n {
            q.schedule_at(rng.uniform() * 100.0, i);
        }
        let mut last = f64::MIN;
        while let Some((t, _)) = q.pop() {
            prop_assert!(t >= last, "time went backwards: {t} < {last}");
            last = t;
        }
        Ok(())
    });
}
