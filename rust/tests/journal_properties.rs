//! Property suite for the sweep trial journal (`exp::sweep`): across
//! random trial interleavings, duplicate records, torn/truncated final
//! lines, and unknown-version records, the reader must recover exactly
//! the longest valid prefix, replay must be idempotent, and
//! journaled-complete trial keys must be a subset of the sweep's own
//! spec expansion.

use std::collections::BTreeSet;

use cse_fsl::exp::common::{Scale, CACHE_VERSION};
use cse_fsl::exp::sweep::{
    builtin, journaled_complete, recover, TrialEntry, TrialStatus, JOURNAL_VERSION,
};
use cse_fsl::prop_assert;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

/// A random journal entry over a small key pool (collisions are the
/// point: duplicates are a journal fact of life under resume).
fn random_entry(rng: &mut Rng) -> TrialEntry {
    let key = format!("trial-key-{}", rng.below(6));
    let status = if rng.below(4) == 0 { TrialStatus::Failed } else { TrialStatus::Ok };
    let record = if status == TrialStatus::Ok {
        format!("cache/mock/{key}.json")
    } else {
        String::new()
    };
    TrialEntry {
        key,
        // Mostly current-version records, sometimes a stale schema.
        cache_version: if rng.below(5) == 0 { CACHE_VERSION + 1 } else { CACHE_VERSION },
        status,
        digest: rng.next_u64(),
        record,
    }
}

/// A random journal: its entries, their rendered lines, and the full
/// byte image.
fn random_journal(rng: &mut Rng) -> (Vec<TrialEntry>, Vec<String>, Vec<u8>) {
    let n = 1 + rng.below(8) as usize;
    let entries: Vec<TrialEntry> = (0..n).map(|_| random_entry(rng)).collect();
    let lines: Vec<String> = entries.iter().map(|e| format!("{}\n", e.to_line())).collect();
    let bytes = lines.concat().into_bytes();
    (entries, lines, bytes)
}

#[test]
fn entry_lines_roundtrip() {
    prop::check("entry_lines_roundtrip", |rng| {
        let e = random_entry(rng);
        let line = e.to_line();
        prop_assert!(!line.contains('\n'), "entry rendered with embedded newline: {line:?}");
        let back = TrialEntry::parse(&line)
            .map_err(|err| format!("own line failed to parse: {err}"))?;
        prop_assert!(back == e, "round-trip changed the entry: {e:?} -> {back:?}");
        Ok(())
    });
}

#[test]
fn recover_is_exact_longest_valid_prefix_under_truncation() {
    prop::check("recover_truncation_prefix", |rng| {
        let (entries, lines, bytes) = random_journal(rng);
        // Cut the byte image anywhere, including line boundaries and
        // cut=0 / cut=len: recovery must return exactly the entries
        // whose full line (newline included) survives the cut.
        let cut = rng.below(bytes.len() as u64 + 1) as usize;
        let (got, valid) = recover(&bytes[..cut]);
        let mut boundary = 0usize;
        let mut want = 0usize;
        for line in &lines {
            if boundary + line.len() <= cut {
                boundary += line.len();
                want += 1;
            } else {
                break;
            }
        }
        prop_assert!(valid == boundary, "valid bytes {valid} != intact-line bytes {boundary}");
        prop_assert!(
            got == entries[..want],
            "cut at {cut}: recovered {} entries, wanted {want}",
            got.len()
        );
        Ok(())
    });
}

#[test]
fn recover_stops_at_corrupt_or_unknown_version_lines() {
    prop::check("recover_corruption_prefix", |rng| {
        let (entries, lines, _) = random_journal(rng);
        // Replace the line at position p with either garbage or a
        // structurally valid record from an unknown journal version.
        let p = rng.below(lines.len() as u64) as usize;
        let bad = match rng.below(3) {
            0 => "not json at all\n".to_string(),
            1 => format!("{}\n", &lines[p][..lines[p].len() / 2]),
            // `to_line()` is compact JSON: no space after the colon.
            _ => format!(
                "{}\n",
                entries[p].to_line().replace(
                    &format!("\"journal_version\":{JOURNAL_VERSION}"),
                    "\"journal_version\":99",
                )
            ),
        };
        let mut doctored = String::new();
        for (i, line) in lines.iter().enumerate() {
            doctored.push_str(if i == p { &bad } else { line });
        }
        let (got, valid) = recover(doctored.as_bytes());
        let boundary: usize = lines[..p].iter().map(|l| l.len()).sum();
        prop_assert!(
            got == entries[..p],
            "corruption at line {p}: recovered {} entries, wanted {p}",
            got.len()
        );
        prop_assert!(valid == boundary, "valid bytes {valid} != prefix bytes {boundary}");
        Ok(())
    });
}

#[test]
fn recover_replay_is_idempotent() {
    prop::check("recover_replay_idempotent", |rng| {
        let (_, _, mut bytes) = random_journal(rng);
        // Optionally tear the tail first: idempotence must hold from
        // any starting image, clean or torn.
        if rng.below(2) == 0 {
            let cut = rng.below(bytes.len() as u64 + 1) as usize;
            bytes.truncate(cut);
        }
        let (first, valid) = recover(&bytes);
        // Replaying exactly the valid prefix (what Journal::resume
        // truncates the file to) is a fixed point.
        let (second, valid2) = recover(&bytes[..valid]);
        prop_assert!(second == first, "replay recovered different entries");
        prop_assert!(valid2 == valid, "replay moved the valid boundary: {valid} -> {valid2}");
        Ok(())
    });
}

#[test]
fn journaled_complete_keys_are_subset_of_spec_expansion() {
    // The real expansion of the built-in `h` sweep at Quick scale.
    let sweeps = builtin("h", Scale::Quick).unwrap();
    let expansion: BTreeSet<String> =
        sweeps[0].trials().unwrap().iter().map(|t| t.spec.key()).collect();
    let keys: Vec<String> = expansion.iter().cloned().collect();
    prop::check("journaled_complete_subset", |rng| {
        // Random mix of in-grid entries, alien keys, failures, stale
        // schema versions, and duplicates.
        let n = rng.below(12) as usize;
        let entries: Vec<TrialEntry> = (0..n)
            .map(|_| {
                let mut e = random_entry(rng);
                if rng.below(2) == 0 {
                    e.key = keys[rng.below(keys.len() as u64) as usize].clone();
                }
                e
            })
            .collect();
        let done = journaled_complete(&entries, &expansion);
        for (key, e) in &done {
            prop_assert!(expansion.contains(key), "completed key {key:?} outside expansion");
            prop_assert!(
                e.status == TrialStatus::Ok,
                "non-Ok entry marked complete: {e:?}"
            );
            prop_assert!(
                e.cache_version == CACHE_VERSION,
                "stale-schema entry marked complete: {e:?}"
            );
        }
        // Last-wins: the map must hold the final Ok record per key.
        for (key, e) in &done {
            let last = entries
                .iter()
                .rev()
                .find(|c| {
                    &c.key == key
                        && c.status == TrialStatus::Ok
                        && c.cache_version == CACHE_VERSION
                })
                .unwrap();
            prop_assert!(last == *e, "completion for {key:?} is not the last Ok entry");
        }
        Ok(())
    });
}
