//! Properties of the wire-compression axis (util/prop harness): the
//! quantizer's reconstruction error is bounded by its closed-form step
//! size, top-k keeps exactly `ceil(frac * n)` entries (the largest
//! magnitudes, verbatim), `Compression::None` is byte-invisible at the
//! RunRecord level (the pre-axis baseline), and the live ledger matches
//! the compressed closed forms in `comm::accounting::predict` for
//! random codec draws. The bit-determinism of compressed rounds across
//! thread counts and dealing policies is pinned separately in
//! tests/determinism_golden.rs.

use cse_fsl::comm::accounting::{predict, WireSizes};
use cse_fsl::comm::compress::Compression;
use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::exp::common::run_to_json;
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn random_tensor(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

#[test]
fn prop_quantize_error_is_bounded_by_the_step_size() {
    prop::check("quantize error <= (max-min)/(2^bits - 1)", |rng| {
        let len = 1 + rng.below(256) as usize;
        let bits = 1 + rng.below(12) as u8;
        let v = random_tensor(rng, len);
        let q = Compression::Quantize { bits };
        let out = q.apply(&v, &Rng::new(rng.next_u64()));
        prop_assert!(out.len() == v.len(), "length changed: {} -> {}", v.len(), out.len());
        let min = v.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let levels = (1u32 << bits) - 1;
        let step = ((max - min) / levels as f32) as f64;
        // Stochastic rounding moves a value at most one full grid step
        // (round-to-nearest would give step/2; the unbiased rounder
        // trades that for zero-mean error). Small slack for the f32
        // arithmetic of the reconstruction.
        let tol = step * (1.0 + 1e-3) + 1e-5;
        for (i, (&x, &y)) in v.iter().zip(out.iter()).enumerate() {
            let err = (y as f64 - x as f64).abs();
            prop_assert!(
                err <= tol,
                "bits={bits} len={len} i={i}: |{y} - {x}| = {err} > step {step}"
            );
            prop_assert!(
                (min as f64 - 1e-5..=max as f64 + 1e-5).contains(&(y as f64)),
                "bits={bits} i={i}: {y} escapes the input range [{min}, {max}]"
            );
        }
        // The range endpoints are exact grid points, so they survive
        // quantization bit-for-bit whatever the stochastic draws did.
        for (i, &x) in v.iter().enumerate() {
            if x == min || x == max {
                prop_assert!(out[i] == x, "endpoint {x} at {i} moved to {}", out[i]);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_keeps_exactly_ceil_frac_n_largest_entries() {
    prop::check("topk keeps ceil(frac*n) largest magnitudes verbatim", |rng| {
        let n = 1 + rng.below(300) as usize;
        let frac = (1 + rng.below(20) as u32) as f32 / 20.0;
        // Distinct nonzero magnitudes (1..=n, shuffled, random signs) so
        // "kept" vs "dropped" is unambiguous and countable.
        let mut v: Vec<f32> = (0..n)
            .map(|i| {
                let mag = (i + 1) as f32;
                if rng.below(2) == 0 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        let t = Compression::TopK { frac };
        let out = t.apply(&v, &Rng::new(rng.next_u64()));
        prop_assert!(out.len() == v.len(), "length changed");
        let kept = Compression::kept_count(frac, n as u64) as usize;
        prop_assert!(
            kept == (frac as f64 * n as f64).ceil() as usize,
            "kept_count {kept} != ceil({frac} * {n})"
        );
        let survivors: Vec<usize> = (0..n).filter(|&i| out[i] != 0.0).collect();
        prop_assert!(
            survivors.len() == kept,
            "n={n} frac={frac}: {} survivors != kept {kept}",
            survivors.len()
        );
        let min_kept =
            survivors.iter().map(|&i| v[i].abs()).fold(f32::INFINITY, f32::min);
        for i in 0..n {
            if out[i] != 0.0 {
                // Survivors pass through verbatim (top-k sparsifies, it
                // does not re-encode the kept values).
                prop_assert!(out[i] == v[i], "survivor {i}: {} != {}", out[i], v[i]);
            } else {
                prop_assert!(
                    v[i].abs() <= min_kept,
                    "dropped |{}| at {i} outranks kept minimum {min_kept}",
                    v[i]
                );
            }
        }
        // The wire cost is the sparse encoding: kept (index, value) pairs.
        prop_assert!(
            t.wire_bytes(n as u64) == kept as u64 * 8,
            "wire_bytes {} != {kept} * 8",
            t.wire_bytes(n as u64)
        );
        Ok(())
    });
}

#[test]
fn prop_apply_is_deterministic_in_the_rng() {
    prop::check("equal rng => equal output", |rng| {
        let v = random_tensor(rng, 1 + rng.below(128) as usize);
        let seed = rng.next_u64();
        for c in [
            Compression::None,
            Compression::Quantize { bits: 1 + rng.below(16) as u8 },
            Compression::TopK { frac: (1 + rng.below(20) as u32) as f32 / 20.0 },
        ] {
            let a = c.apply(&v, &Rng::new(seed));
            let b = c.apply(&v, &Rng::new(seed));
            prop_assert!(a == b, "{c} is not deterministic given an equal rng");
        }
        Ok(())
    });
}

/// One small CSE_FSL run over the mock engine at a given codec.
fn run_record(compression: Compression) -> cse_fsl::metrics::recorder::RunRecord {
    let e = MockEngine::small(42);
    let train = generate(&spec(), 64, 5);
    let test = generate(&spec(), 16, 6);
    let cfg = TrainConfig {
        rounds: 6,
        agg_every: 2,
        eval_every: 3,
        eval_max_batches: 2,
        ..TrainConfig::new(Method::CseFsl).with_h(2).with_compression(compression)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition: iid(&train, 4, &mut Rng::new(7)),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "codec".into(),
    };
    let mut tr = Trainer::new(&e, cfg, setup).unwrap();
    tr.run().unwrap()
}

#[test]
fn compression_none_is_byte_invisible_and_lossy_codecs_are_not() {
    // `Compression::None` is the pre-axis baseline: a config that never
    // mentions the axis and one that names it explicitly must produce
    // byte-identical RunRecord JSON — the new axis default cannot move
    // any recorded number. A lossy codec on the same seed must move
    // them (coarser activations change the training trajectory and the
    // wire bytes).
    let implicit = {
        let e = MockEngine::small(42);
        let train = generate(&spec(), 64, 5);
        let test = generate(&spec(), 16, 6);
        let cfg = TrainConfig {
            rounds: 6,
            agg_every: 2,
            eval_every: 3,
            eval_max_batches: 2,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        };
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition: iid(&train, 4, &mut Rng::new(7)),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "codec".into(),
        };
        let mut tr = Trainer::new(&e, cfg, setup).unwrap();
        tr.run().unwrap()
    };
    let explicit_none = run_record(Compression::None);
    assert_eq!(
        run_to_json(&implicit).pretty(),
        run_to_json(&explicit_none).pretty(),
        "Compression::None must be byte-identical to never naming the axis"
    );
    let q4 = run_record(Compression::Quantize { bits: 4 });
    assert_ne!(
        run_to_json(&explicit_none).pretty(),
        run_to_json(&q4).pretty(),
        "a lossy codec must change the run"
    );
    // And repeated compressed runs reproduce bit-for-bit.
    let q4_again = run_record(Compression::Quantize { bits: 4 });
    assert_eq!(run_to_json(&q4).pretty(), run_to_json(&q4_again).pretty());
}

#[test]
fn prop_compressed_ledger_matches_predicted_closed_forms() {
    prop::check("compressed ledger == predict closed forms", |rng| {
        let compression = match rng.below(3) {
            0 => Compression::None,
            1 => Compression::Quantize { bits: 1 + rng.below(16) as u8 },
            _ => Compression::TopK { frac: (1 + rng.below(20) as u32) as f32 / 20.0 },
        };
        let n = 1 + rng.below(4) as usize;
        let method = Method::ALL[rng.below(4) as usize];
        let rounds = 1 + rng.below(6) as usize;
        let agg_every = 1 + rng.below(rounds as u64 + 2) as usize;
        let e = MockEngine::small(rng.next_u64());
        let train = generate(&spec(), n * 16, rng.next_u64());
        let test = generate(&spec(), 8, rng.next_u64());
        let cfg = TrainConfig {
            rounds,
            agg_every,
            eval_every: 0,
            ..TrainConfig::new(method).with_compression(compression)
        };
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition: iid(&train, n, &mut Rng::new(rng.next_u64())),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "prop".into(),
        };
        let mut tr = Trainer::new(&e, cfg, setup)?;
        tr.run().map_err(|e| e.to_string())?;
        let wires = WireSizes::new(e.smashed_len, e.client_size(), e.aux_size());
        let expected = predict::run_kind_bytes(
            method.spec().traffic(),
            compression,
            n as u64,
            e.batch as u64,
            rounds as u64,
            agg_every as u64,
            &wires,
        );
        for (kind, bytes) in expected {
            prop_assert!(
                tr.ledger.bytes_of(kind) == bytes,
                "{method} {compression} n={n} rounds={rounds} agg={agg_every}: \
                 {kind:?} measured {} != predicted {bytes}",
                tr.ledger.bytes_of(kind)
            );
        }
        Ok(())
    });
}
