//! Streaming-vs-resident equivalence: the population engine must be
//! invisible at resident scale.
//!
//! The contract (coordinator/population.rs): a population run over a
//! `ClientSource::Partition` — same partition, same config, contract
//! defaults (full availability, no straggler cutoff) — produces a
//! `RunRecord` **bit-identical** to the resident engine's, even though
//! no client state outlives its activation window. And the population
//! engine inherits the repo's older golden contract: thread counts and
//! dealing policies never change results. The non-contract knobs
//! (a churn model below full availability, a straggler cutoff) must
//! visibly change results — that is what they are for — while still
//! completing cleanly, and churned runs keep both the engine
//! equivalence and the golden contract.

use cse_fsl::coordinator::config::{Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::{ClientUpdate, Compression, Method, MethodSpec};
use cse_fsl::coordinator::population::{ClientSource, PopulationSetup};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::exp::common::run_to_json;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::sched::SchedPolicy;
use cse_fsl::sim::churn::{ChurnConfig, ChurnModel, ResiliencePolicy};
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn config(seed: u64, participation: usize, rounds: usize) -> TrainConfig {
    TrainConfig {
        participation,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        seed,
        ..TrainConfig::new(Method::CseFsl).with_h(2)
    }
    .with_rounds(rounds)
}

/// The resident reference run.
fn run_resident(train: &Dataset, test: &Dataset, cfg: TrainConfig) -> String {
    let e = MockEngine::small(42);
    let setup = TrainerSetup {
        train,
        test,
        partition: iid(train, 5, &mut Rng::new(7)),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "golden".to_string(),
    };
    let mut tr = Trainer::new(&e, cfg, setup).unwrap();
    run_to_json(&tr.run().unwrap()).pretty()
}

/// The same run through the streaming population engine.
fn run_population(train: &Dataset, test: &Dataset, cfg: TrainConfig) -> String {
    let e = MockEngine::small(42);
    let source = ClientSource::Partition(iid(train, 5, &mut Rng::new(7)));
    let setup = PopulationSetup::new(train, test, source, NetModel::edge_default(), "golden");
    let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
    run_to_json(&tr.run().unwrap()).pretty()
}

#[test]
fn population_partition_bit_identical_to_resident() {
    // Equivalence property over seeds × participation: full rounds
    // (every client active every round) and k-of-n sampling (clients
    // activate late, retire, and reactivate — the lazy-lifecycle path
    // that replays missed aggregation broadcasts).
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    for seed in [1u64, 5, 9] {
        for participation in [0usize, 3] {
            let resident = run_resident(&train, &test, config(seed, participation, 12));
            let streamed = run_population(&train, &test, config(seed, participation, 12));
            assert_eq!(
                resident.as_bytes(),
                streamed.as_bytes(),
                "seed={seed} participation={participation}: RunRecord diverged"
            );
        }
    }
}

#[test]
fn population_bit_identical_across_threads_and_sched() {
    // The population fan-out goes through the same dealing machinery as
    // the resident engine, so it inherits the golden contract: thread
    // counts and dealing policies are invisible in results.
    let train = dataset(120, 3);
    let test = dataset(24, 4);
    let reference = run_population(&train, &test, config(1, 3, 12));
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let cfg = TrainConfig {
                parallelism: Parallelism::Threads(threads),
                sched,
                ..config(1, 3, 12)
            };
            let par = run_population(&train, &test, cfg);
            assert_eq!(
                reference.as_bytes(),
                par.as_bytes(),
                "sched={sched} threads={threads}: RunRecord diverged"
            );
        }
    }
}

#[test]
fn compressed_population_bit_identical_to_resident() {
    // The wire codec runs inside `run_local_client`, which both engines
    // share — so a compressed population run must stay bit-identical to
    // the compressed resident reference (same split of the round
    // snapshot rng on both paths), across thread counts and dealing
    // policies, while differing from the uncompressed contract run.
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    let compress = |cfg: TrainConfig| TrainConfig {
        spec: cfg.spec.with_compression(Compression::Quantize { bits: 4 }),
        ..cfg
    };
    let resident = run_resident(&train, &test, compress(config(1, 3, 12)));
    let streamed = run_population(&train, &test, compress(config(1, 3, 12)));
    assert_eq!(
        resident.as_bytes(),
        streamed.as_bytes(),
        "quantize4: population RunRecord diverged from resident"
    );
    assert_ne!(
        streamed,
        run_population(&train, &test, config(1, 3, 12)),
        "the codec must change results"
    );
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let cfg = TrainConfig {
                parallelism: Parallelism::Threads(threads),
                sched,
                ..compress(config(1, 3, 12))
            };
            let par = run_population(&train, &test, cfg);
            assert_eq!(
                streamed.as_bytes(),
                par.as_bytes(),
                "quantize4 sched={sched} threads={threads}: RunRecord diverged"
            );
        }
    }
}

/// `config()` with the gradient-estimator update rule swapped in: the
/// same aux-local round body between alignments, plus the true-gradient
/// downlink + estimator re-fit every `align_every`-th round.
fn sage_config(seed: u64, participation: usize, rounds: usize) -> TrainConfig {
    let base = config(seed, participation, rounds);
    TrainConfig {
        spec: MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: 3, clip: 0.0 },
            ..base.spec
        },
        ..base
    }
}

#[test]
fn sage_population_bit_identical_to_resident() {
    // The alignment pass runs on the carried cohort exactly as it runs
    // on the resident client vector (same rng splits off the round
    // snapshot, same canonical client order), so the streaming engine
    // stays invisible for the sage rule too — at full rounds and under
    // k-of-n sampling, uncompressed and with the codec biting on the
    // alignment downlink.
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    for participation in [0usize, 3] {
        let resident = run_resident(&train, &test, sage_config(1, participation, 12));
        let streamed = run_population(&train, &test, sage_config(1, participation, 12));
        assert_eq!(
            resident.as_bytes(),
            streamed.as_bytes(),
            "sage participation={participation}: RunRecord diverged"
        );
    }
    let compress = |cfg: TrainConfig| TrainConfig {
        spec: cfg.spec.with_compression(Compression::Quantize { bits: 4 }),
        ..cfg
    };
    let resident = run_resident(&train, &test, compress(sage_config(1, 3, 12)));
    let streamed = run_population(&train, &test, compress(sage_config(1, 3, 12)));
    assert_eq!(
        resident.as_bytes(),
        streamed.as_bytes(),
        "sage quantize4: population RunRecord diverged from resident"
    );
    // The estimator rule is a live axis in the population engine: its
    // results differ from the aux-local neighbour's.
    assert_ne!(
        run_population(&train, &test, sage_config(1, 0, 12)),
        run_population(&train, &test, config(1, 0, 12)),
        "alignment must change population results"
    );
    // And the population fan-out keeps the golden contract on sage runs.
    let reference = run_population(&train, &test, sage_config(1, 3, 12));
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let cfg = TrainConfig {
                parallelism: Parallelism::Threads(threads),
                sched,
                ..sage_config(1, 3, 12)
            };
            let par = run_population(&train, &test, cfg);
            assert_eq!(
                reference.as_bytes(),
                par.as_bytes(),
                "sage sched={sched} threads={threads}: RunRecord diverged"
            );
        }
    }
}

#[test]
fn pool_source_activates_only_the_sampled_working_set() {
    // Fleet mode: a Pool source over a shared sample pool. Only sampled
    // participants are ever materialized, so the working set is bounded
    // by rounds × cohort regardless of n.
    let train = dataset(120, 5);
    let test = dataset(24, 6);
    let e = MockEngine::small(42);
    let n = 512usize;
    let source =
        ClientSource::Pool { n_clients: n, samples_per_client: 24, pool_len: train.len() };
    let setup = PopulationSetup::new(&train, &test, source, NetModel::edge_default(), "pool");
    let cfg = TrainConfig {
        participation: 16,
        agg_every: 2,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        seed: 1,
        ..TrainConfig::new(Method::CseFsl).with_h(2)
    }
    .with_rounds(6);
    let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
    let rec = tr.run().unwrap();
    assert_eq!(rec.rounds.len(), 6);
    assert_eq!(tr.n_clients(), n);
    assert!(
        rec.clients_activated <= 6 * 16 && rec.clients_activated < n,
        "activated {} of {n}",
        rec.clients_activated
    );
    assert_eq!(rec.clients_activated, tr.clients_activated());
    assert!(
        (0.0..=1.0).contains(&rec.shard_label_divergence),
        "{}",
        rec.shard_label_divergence
    );
    // The record reflects the full fleet, not the working set.
    assert!(rec.server_storage_params > 0);
    // Losses are finite — the shared pool trains like any IID split.
    assert!(rec.rounds.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn availability_and_straggler_dropout_change_results_but_complete() {
    let train = dataset(120, 7);
    let test = dataset(24, 8);
    let contract = run_population(&train, &test, config(1, 0, 12));
    // Straggler cutoff 0: in every round only the earliest arrival (and
    // exact ties) survives apply_cutoff; everything else is dropped.
    // Iid{0.6} thins every round's cohort on top of that.
    let e = MockEngine::small(42);
    let source = ClientSource::Partition(iid(&train, 5, &mut Rng::new(7)));
    let setup =
        PopulationSetup::new(&train, &test, source, NetModel::edge_default(), "golden");
    let cfg = config(1, 0, 12).with_churn(ChurnConfig {
        model: ChurnModel::Iid { p: 0.6 },
        policy: ResiliencePolicy::Cutoff { secs: 0.0 },
        ..ChurnConfig::default()
    });
    let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
    let rec = tr.run().unwrap();
    assert_eq!(rec.rounds.len(), 12);
    let pop = tr.population.as_ref().unwrap();
    assert!(pop.arrivals > 0, "no arrivals processed");
    assert!(
        tr.churn_stats.stragglers_dropped > 0,
        "cutoff 0 with distinct delays must drop stragglers"
    );
    assert!(
        tr.churn_stats.clients_dropped > 0,
        "Iid{{0.6}} over 12 rounds must drop someone"
    );
    assert_eq!(rec.stragglers_dropped, tr.churn_stats.stragglers_dropped);
    assert_eq!(rec.clients_dropped, tr.churn_stats.clients_dropped);
    assert_ne!(
        contract,
        run_to_json(&rec).pretty(),
        "dropout knobs must visibly change results"
    );
}

#[test]
fn churned_population_bit_identical_to_resident_and_across_threads() {
    // The churn filter runs before the cohort is handed to the fan-out,
    // off non-mutating (round, id) splits of the shared root — so a
    // correlated-outage run with mid-round failures and quorum
    // re-sampling keeps both the engine equivalence and the golden
    // contract (any thread count, any dealing policy).
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    let churned = |cfg: TrainConfig| {
        cfg.with_churn(ChurnConfig {
            model: ChurnModel::Correlated { clusters: 2, p_outage: 0.3 },
            fail_rate: 0.2,
            policy: ResiliencePolicy::Quorum { min_frac: 0.8, resample: true },
        })
    };
    let resident = run_resident(&train, &test, churned(config(1, 3, 12)));
    let streamed = run_population(&train, &test, churned(config(1, 3, 12)));
    assert_eq!(
        resident.as_bytes(),
        streamed.as_bytes(),
        "churned population RunRecord diverged from resident"
    );
    assert_ne!(
        streamed,
        run_population(&train, &test, config(1, 3, 12)),
        "churn must change results"
    );
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let cfg = TrainConfig {
                parallelism: Parallelism::Threads(threads),
                sched,
                ..churned(config(1, 3, 12))
            };
            let par = run_population(&train, &test, cfg);
            assert_eq!(
                streamed.as_bytes(),
                par.as_bytes(),
                "churn sched={sched} threads={threads}: RunRecord diverged"
            );
        }
    }
}
