//! Timeline invariants under the property harness: whatever the method,
//! fan-out, participation, and horizon, the simulated schedule must be
//! physically consistent — no actor does two things at once, the server
//! idle fraction is a fraction, spans are well-formed, and running
//! longer never ends earlier.

use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn setup<'a>(train: &'a Dataset, test: &'a Dataset, n: usize, seed: u64) -> TrainerSetup<'a> {
    TrainerSetup {
        train,
        test,
        partition: iid(train, n, &mut Rng::new(seed)),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "tl".into(),
    }
}

#[test]
fn prop_no_actor_ever_overlaps_itself() {
    prop::check("actor schedules are consistent", |rng| {
        let n = 2 + rng.below(4) as usize;
        let method = Method::ALL[rng.below(4) as usize];
        // Aux-local presets take random periods (including FSL_AN's
        // spec-only h > 1 points); server-grad presets are h = 1.
        let h = if method.spec().update.uses_aux() { 1 + rng.below(3) as usize } else { 1 };
        let rounds = 1 + rng.below(8) as usize;
        let agg_every = 1 + rng.below(rounds as u64 + 2) as usize;
        let participation = rng.below(n as u64 + 1) as usize; // 0 = all
        let parallelism = if rng.below(2) == 0 {
            Parallelism::Sequential
        } else {
            Parallelism::Threads(1 + rng.below(4) as usize)
        };
        let e = MockEngine::small(rng.next_u64());
        let train = generate(&spec(), n * 16, rng.next_u64());
        let test = generate(&spec(), 8, rng.next_u64());
        let cfg = TrainConfig {
            rounds,
            agg_every,
            participation,
            parallelism,
            eval_every: 0,
            ..TrainConfig::new(method).with_h(h)
        };
        let mut tr =
            Trainer::new(&e, cfg, setup(&train, &test, n, rng.next_u64()))?;
        let rec = tr.run().map_err(|e| e.to_string())?;

        // Well-formed spans.
        for s in &tr.timeline.spans {
            prop_assert!(
                s.end >= s.start && s.start >= 0.0,
                "malformed span {s:?} ({method}, {parallelism:?})"
            );
        }
        // No client is ever in two places at once.
        for id in tr.timeline.client_ids() {
            let overlap = tr.timeline.max_overlap(Some(id));
            prop_assert!(
                overlap <= 1e-9,
                "client {id} overlaps itself by {overlap} ({method}, h={h}, {parallelism:?})"
            );
        }
        // Neither is the server.
        let overlap = tr.timeline.max_overlap(None);
        prop_assert!(
            overlap <= 1e-9,
            "server overlaps itself by {overlap} ({method}, {parallelism:?})"
        );
        // Idle fraction is a fraction; end time covers every span.
        prop_assert!(
            (0.0..=1.0).contains(&rec.server_idle_fraction),
            "idle fraction {} out of range",
            rec.server_idle_fraction
        );
        let max_end =
            tr.timeline.spans.iter().map(|s| s.end).fold(0.0f64, f64::max);
        prop_assert!(
            rec.sim_time == max_end,
            "sim_time {} != latest span end {max_end}",
            rec.sim_time
        );
        Ok(())
    });
}

#[test]
fn end_time_is_monotone_in_rounds() {
    let train = generate(&spec(), 96, 11);
    let test = generate(&spec(), 16, 12);
    for method in Method::ALL {
        for parallelism in [Parallelism::Sequential, Parallelism::Threads(3)] {
            let mut last = 0.0f64;
            for rounds in [2usize, 5, 9] {
                let e = MockEngine::small(42);
                let cfg = TrainConfig {
                    rounds,
                    agg_every: 4,
                    eval_every: 0,
                    parallelism,
                    ..TrainConfig::new(method)
                };
                let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, 7)).unwrap();
                let rec = tr.run().unwrap();
                assert!(
                    rec.sim_time > last,
                    "{method} {parallelism:?}: end_time not monotone \
                     ({last} -> {} at rounds={rounds})",
                    rec.sim_time
                );
                last = rec.sim_time;
            }
        }
    }
}

#[test]
fn end_time_prefix_property_across_horizons() {
    // Stronger than monotonicity: a shorter run is a prefix of a longer
    // one, so its per-round sim_time series must match exactly.
    let train = generate(&spec(), 96, 13);
    let test = generate(&spec(), 16, 14);
    let run = |rounds: usize| {
        let e = MockEngine::small(42);
        let cfg = TrainConfig {
            rounds,
            agg_every: 3,
            eval_every: 0,
            ..TrainConfig::new(Method::CseFsl)
        };
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 4, 7)).unwrap();
        let rec = tr.run().unwrap();
        rec.rounds.iter().map(|r| r.sim_time).collect::<Vec<_>>()
    };
    let short = run(4);
    let long = run(10);
    assert_eq!(short[..], long[..4], "shorter horizon must be a prefix of the longer one");
}

#[test]
fn splitfed_clients_block_but_stay_consistent() {
    // FSL_MC's round-trip schedule (fwd, upload, server, download, bwd)
    // threads one client through five span kinds; the per-actor
    // non-overlap invariant must survive the interleaving, and the
    // server must process one update per participant per round.
    let train = generate(&spec(), 64, 15);
    let test = generate(&spec(), 16, 16);
    let e = MockEngine::small(42);
    let rounds = 6;
    let n = 4;
    let cfg = TrainConfig {
        rounds,
        agg_every: 100,
        eval_every: 0,
        parallelism: Parallelism::Threads(2),
        arrival: ArrivalOrder::ByDelay,
        ..TrainConfig::new(Method::FslMc)
    };
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, n, 7)).unwrap();
    tr.run().unwrap();
    for id in tr.timeline.client_ids() {
        assert!(tr.timeline.max_overlap(Some(id)) <= 1e-9);
    }
    assert!(tr.timeline.max_overlap(None) <= 1e-9);
    assert_eq!(tr.server.updates, (rounds * n) as u64);
}
