//! Scheduling invariants (util/prop harness).
//!
//! 1. **Policy invisibility** — across random configurations, the
//!    `RunRecord` JSON is bit-identical for every `SchedPolicy` at
//!    every thread count (the determinism contract that keeps `sched`
//!    out of `RunSpec::key`).
//! 2. **Balanced shard map** — `ShardMap::balanced` partitions are a
//!    permutation of the clients (every client in exactly one shard,
//!    no shard empty) and the max shard load respects the greedy LPT
//!    bound `total/k + (1 - 1/k)·c_max`.
//! 3. **Fan-out order** — `sched::fanout` returns results in canonical
//!    item order for every policy and worker count.
//! 4. **Timeline efficiency metrics** — the critical-path lower bound
//!    never exceeds the makespan, and per-lane busy accounting matches
//!    the executor count.

use cse_fsl::coordinator::config::{Parallelism, ShardMapKind, TrainConfig};
use cse_fsl::coordinator::methods::{Method, ServerTopology};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::coordinator::server::ShardMap;
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::exp::common::run_to_json;
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::sched::{self, SchedPolicy};
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

/// One run at a given parallelism/policy over a shared random scenario.
struct Scenario {
    method: Method,
    n: usize,
    h: usize,
    rounds: usize,
    server_shards: usize,
    shard_map: ShardMapKind,
    engine_seed: u64,
    data_seed: u64,
    part_seed: u64,
}

fn random_scenario(rng: &mut Rng) -> Scenario {
    let n = 2 + rng.below(4) as usize; // 2..=5 clients
    let method = Method::ALL[rng.below(4) as usize];
    // Aux-local presets take random periods — FSL_AN's h > 1 draws
    // exercise the spec-only AuxLocal×Period×PerClient scenario.
    let h = if method.spec().update.uses_aux() { 1 + rng.below(3) as usize } else { 1 };
    let rounds = 2 + rng.below(5) as usize;
    let server_shards = match method.spec().topology {
        ServerTopology::PerClient => 1,
        ServerTopology::Shared => 1 + rng.below(n as u64) as usize,
    };
    // Balanced maps need k >= 2; mix them in whenever sharded.
    let shard_map = if server_shards >= 2 && rng.below(2) == 1 {
        ShardMapKind::Balanced
    } else {
        ShardMapKind::Contiguous
    };
    Scenario {
        method,
        n,
        h,
        rounds,
        server_shards,
        shard_map,
        engine_seed: rng.next_u64(),
        data_seed: rng.next_u64(),
        part_seed: rng.next_u64(),
    }
}

fn run_scenario(
    s: &Scenario,
    parallelism: Parallelism,
    sched: SchedPolicy,
) -> Result<cse_fsl::metrics::recorder::RunRecord, String> {
    let e = MockEngine::small(s.engine_seed);
    let train = generate(&spec(), s.n * 16, s.data_seed);
    let test = generate(&spec(), 8, s.data_seed ^ 0x5A);
    let cfg = TrainConfig {
        rounds: s.rounds,
        agg_every: 3,
        eval_every: 2,
        eval_max_batches: 1,
        parallelism,
        sched,
        server_shards: s.server_shards,
        shard_map: s.shard_map,
        ..TrainConfig::new(s.method).with_h(s.h)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition: iid(&train, s.n, &mut Rng::new(s.part_seed)),
        net: NetModel::heavy_tailed(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "sched-prop".into(),
    };
    let mut tr = Trainer::new(&e, cfg, setup)?;
    tr.run().map_err(|e| e.to_string())
}

#[test]
fn prop_runrecord_bit_identical_across_policies_and_threads() {
    prop::check("RunRecord identical across SchedPolicy x threads", |rng| {
        let s = random_scenario(rng);
        let threads = 2 + rng.below(3) as usize; // 2..=4 workers
        let reference = run_to_json(&run_scenario(
            &s,
            Parallelism::Sequential,
            SchedPolicy::RoundRobin,
        )?)
        .pretty();
        for sched in SchedPolicy::ALL {
            for par in [Parallelism::Threads(1), Parallelism::Threads(threads)] {
                let json = run_to_json(&run_scenario(&s, par, sched)?).pretty();
                prop_assert!(
                    json == reference,
                    "{} n={} h={} rounds={} k={} map={:?}: {sched} at {par:?} diverged",
                    s.method,
                    s.n,
                    s.h,
                    s.rounds,
                    s.server_shards,
                    s.shard_map
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_balanced_shard_map_is_bounded_permutation() {
    prop::check("ShardMap::balanced permutation + LPT bound", |rng| {
        let n = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(n as u64) as usize;
        let costs: Vec<f64> = (0..n).map(|_| rng.lognormal(1.0, 1.2)).collect();
        let map = ShardMap::balanced(n, k, &costs);
        prop_assert!(map.shards() == k, "shard count {} != {k}", map.shards());
        prop_assert!(map.n_clients() == n, "client count {} != {n}", map.n_clients());
        // Permutation: the union of shard cohorts is 0..n, each exactly
        // once, and no shard is empty.
        let mut seen: Vec<usize> = (0..k).flat_map(|s| map.clients_of(s)).collect();
        seen.sort_unstable();
        prop_assert!(
            seen == (0..n).collect::<Vec<_>>(),
            "cohorts are not a permutation: {seen:?}"
        );
        for shard in 0..k {
            prop_assert!(!map.clients_of(shard).is_empty(), "shard {shard} empty (k={k} n={n})");
        }
        // Load balance: max shard load within the greedy LPT bound.
        let load = |s: usize| map.clients_of(s).iter().map(|&c| costs[c]).sum::<f64>();
        let max_load = (0..k).map(load).fold(0.0f64, f64::max);
        let bound = sched::greedy_bound(&costs, k);
        prop_assert!(
            max_load <= bound + 1e-9,
            "max load {max_load} exceeds LPT bound {bound} (n={n} k={k})"
        );
        Ok(())
    });
}

#[test]
fn prop_fanout_returns_canonical_order() {
    prop::check("fanout canonical order", |rng| {
        let n = rng.below(40) as usize;
        let workers = 1 + rng.below(8) as usize;
        let policy = SchedPolicy::ALL[rng.below(3) as usize];
        let costs: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 10.0)).collect();
        let items: Vec<usize> = (0..n).collect();
        let out = sched::fanout(policy, workers, items, &costs, |pos, x| {
            if pos != x {
                return Err(format!("work saw pos {pos} for item {x}"));
            }
            Ok(x.wrapping_mul(3))
        })
        .map_err(|e| format!("{policy} w={workers} n={n}: {e:?}"))?;
        prop_assert!(
            out == (0..n).map(|x| x.wrapping_mul(3)).collect::<Vec<_>>(),
            "{policy} w={workers} n={n}: out of order"
        );
        Ok(())
    });
}

#[test]
fn prop_critical_path_bounds_makespan() {
    prop::check("critical path <= makespan; lanes sized to executors", |rng| {
        let s = random_scenario(rng);
        let rec = run_scenario(&s, Parallelism::Sequential, SchedPolicy::RoundRobin)?;
        prop_assert!(
            rec.critical_path <= rec.sim_time + 1e-9,
            "critical path {} exceeds makespan {} ({} k={})",
            rec.critical_path,
            rec.sim_time,
            s.method,
            s.server_shards
        );
        prop_assert!(rec.critical_path > 0.0, "critical path must be positive after a run");
        let lanes = match s.method.spec().topology {
            ServerTopology::PerClient => 1,
            ServerTopology::Shared => s.server_shards,
        };
        prop_assert!(
            rec.lane_busy.len() == lanes,
            "lane_busy len {} != executor count {lanes}",
            rec.lane_busy.len()
        );
        let eff = rec.sched_efficiency();
        prop_assert!((0.0..=1.0).contains(&eff), "efficiency {eff} out of range");
        Ok(())
    });
}
