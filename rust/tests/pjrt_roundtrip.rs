//! Integration: the real AOT artifacts load, compile, and execute via
//! PJRT, and the training entries behave like training steps (loss falls,
//! shapes line up, dropout replays). Requires `make artifacts`.

use std::sync::Arc;

use cse_fsl::model::init::init_flat;
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::{artifacts_dir, SplitEngine};
use cse_fsl::util::prng::Rng;

fn setup(dataset: &str, aux: &str) -> Option<(Arc<PjrtRuntime>, PjrtEngine, Manifest)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    // Also skip when the runtime itself is unavailable (a build without
    // `--features pjrt` carries an always-erroring stub).
    let rt = match PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e}");
            return None;
        }
    };
    let engine = PjrtEngine::new(rt.clone(), &manifest, dataset, aux).expect("engine");
    Some((rt, engine, manifest))
}

fn rand_batch(e: &impl SplitEngine, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..e.batch() * e.input_len())
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let y: Vec<i32> = (0..e.batch()).map(|_| rng.below(e.classes() as u64) as i32).collect();
    (x, y)
}

#[test]
fn femnist_full_split_training_path() {
    let Some((_rt, e, m)) = setup("femnist", "cnn8") else { return };
    let cfg = m.config("femnist").unwrap();
    let mut rng = Rng::new(1);
    let mut xc = init_flat(&cfg.client_layout, &mut rng.split_str("c"));
    let mut ac = init_flat(&cfg.aux("cnn8").unwrap().layout, &mut rng.split_str("a"));
    let mut xs = init_flat(&cfg.server_layout, &mut rng.split_str("s"));
    assert_eq!(xc.len(), 18_816);
    assert_eq!(xs.len(), 1_187_774);
    assert_eq!(ac.len(), 72_006);

    let (x, y) = rand_batch(&e, 2);

    // --- auxiliary-network local training (CSE-FSL client, Eq. (8))
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for i in 0..8 {
        let out = e.client_train_step(&xc, &ac, &x, &y, 0.01, i).unwrap();
        xc = out.new_client;
        ac = out.new_aux;
        first_loss.get_or_insert(out.loss);
        last_loss = out.loss;
        assert!(out.loss.is_finite());
        assert!(out.grad_norm > 0.0);
    }
    assert!(
        last_loss < first_loss.unwrap(),
        "client loss did not fall: {first_loss:?} -> {last_loss}"
    );

    // --- smashed upload + event-triggered server update (Eq. (11))
    let sm = e.client_fwd(&xc, &x, 7).unwrap();
    assert_eq!(sm.len(), e.batch() * e.smashed_len());
    let sm2 = e.client_fwd(&xc, &x, 7).unwrap();
    assert_eq!(sm, sm2, "dropout must replay for equal seeds");
    let sm3 = e.client_fwd(&xc, &x, 8).unwrap();
    assert_ne!(sm, sm3, "different seed must change dropout");

    let mut sfirst = None;
    let mut slast = 0.0;
    for i in 0..8 {
        let out = e.server_train_step(&xs, &sm, &y, 0.005, i).unwrap();
        xs = out.new_server;
        sfirst.get_or_insert(out.loss);
        slast = out.loss;
    }
    assert!(slast < sfirst.unwrap(), "server loss did not fall");

    // --- full-model eval
    let logits = e.eval_step(&xc, &xs, &x).unwrap();
    assert_eq!(logits.len(), e.batch() * e.classes());
    assert!(logits.iter().all(|v| v.is_finite()));

    // --- aux-head eval
    let alogits = e.aux_eval_step(&xc, &ac, &x).unwrap();
    assert_eq!(alogits.len(), e.batch() * e.classes());
}

#[test]
fn femnist_splitfed_grad_path_matches_training_semantics() {
    let Some((_rt, e, m)) = setup("femnist", "mlp") else { return };
    let cfg = m.config("femnist").unwrap();
    let mut rng = Rng::new(3);
    let xc = init_flat(&cfg.client_layout, &mut rng.split_str("c"));
    let xs = init_flat(&cfg.server_layout, &mut rng.split_str("s"));
    let (x, y) = rand_batch(&e, 4);

    let seed = 11;
    let sm = e.client_fwd(&xc, &x, seed).unwrap();
    let out = e.server_fwd_bwd(&xs, &sm, &y, 0.01, seed, 0.0).unwrap();
    assert_eq!(out.grad_smashed.len(), sm.len());
    assert!(out.loss.is_finite());
    let (xc2, gnorm) = e.client_bwd(&xc, &x, &out.grad_smashed, 0.01, seed, 0.0).unwrap();
    assert_eq!(xc2.len(), xc.len());
    assert!(gnorm > 0.0);
    // the update must actually move the client model
    let moved = xc.iter().zip(&xc2).any(|(a, b)| a != b);
    assert!(moved);

    // clipping caps the returned cut-layer gradient
    let clipped = e.server_fwd_bwd(&xs, &sm, &y, 0.01, seed, 1e-3).unwrap();
    let norm: f32 = clipped.grad_smashed.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(norm <= 1e-3 * 1.01, "clip violated: {norm}");
}

#[test]
fn executables_are_cached_per_entry() {
    let Some((rt, e, _m)) = setup("femnist", "cnn2") else { return };
    let (x, y) = rand_batch(&e, 5);
    let xc = vec![0.01f32; e.client_size()];
    let ac = vec![0.01f32; e.aux_size()];
    let before = rt.compiles();
    for i in 0..3 {
        e.client_train_step(&xc, &ac, &x, &y, 0.0, i).unwrap();
    }
    let after = rt.compiles();
    assert_eq!(after - before, 1, "entry must compile exactly once");
}

#[test]
fn lr_zero_is_identity_through_pjrt() {
    let Some((_rt, e, m)) = setup("femnist", "cnn2") else { return };
    let cfg = m.config("femnist").unwrap();
    let mut rng = Rng::new(6);
    let xc = init_flat(&cfg.client_layout, &mut rng.split_str("c"));
    let ac = init_flat(&cfg.aux("cnn2").unwrap().layout, &mut rng.split_str("a"));
    let (x, y) = rand_batch(&e, 7);
    let out = e.client_train_step(&xc, &ac, &x, &y, 0.0, 0).unwrap();
    assert_eq!(out.new_client, xc);
    assert_eq!(out.new_aux, ac);
}
