//! End-to-end driver (DESIGN.md §deliverables): trains the paper's full
//! CIFAR-10 split CNN (client 107,328 + server 960,970 + aux 11,485
//! params) with CSE-FSL for a few hundred client SGD steps on the
//! synthetic CIFAR workload, through the REAL stack — Pallas-kernel HLO
//! executed via PJRT from the Rust coordinator — and logs the loss curve
//! + accuracy. Recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example e2e_cifar [rounds]

use std::time::Instant;

use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{train_test, SyntheticSpec};
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::{artifacts_dir, SplitEngine};
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::csvio::Csv;
use cse_fsl::util::prng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let manifest = Manifest::load(artifacts_dir())
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    let rt = PjrtRuntime::new()?;
    let engine = PjrtEngine::new(rt.clone(), &manifest, "cifar", "cnn27")?;
    let cfg_ds = manifest.config("cifar")?;

    let n_clients = 5;
    let h = 2;
    let (train, test) = train_test(&SyntheticSpec::cifar_like(), 2000, 500, 42);
    let partition = iid(&train, n_clients, &mut Rng::new(7));

    let total_params = engine.client_size() + engine.server_size() + engine.aux_size();
    println!("== e2e: CIFAR split CNN, {total_params} params, CSE-FSL h={h}, {n_clients} clients ==");
    println!(
        "{} client SGD steps total ({} rounds x {} clients x h={})",
        rounds * n_clients * h,
        rounds,
        n_clients,
        h
    );

    let cfg = TrainConfig {
        rounds,
        agg_every: 4,
        lr0: 0.01,
        eval_every: 4,
        eval_max_batches: 4,
        track_grad_norms: true,
        ..TrainConfig::new(Method::CseFsl).with_h(h)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition,
        net: NetModel::edge_default(),
        client_layout: Some(&cfg_ds.client_layout),
        server_layout: Some(&cfg_ds.server_layout),
        aux_layout: Some(&cfg_ds.aux("cnn27")?.layout),
        label: "e2e_cifar".into(),
    };
    let t0 = Instant::now();
    let mut trainer = Trainer::new(&engine, cfg, setup)?;
    let rec = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nround  lr       train_loss  server_loss  grad_norm  acc");
    for r in &rec.rounds {
        println!(
            "{:>5}  {:.5}  {:>10.4}  {:>11.4}  {:>9.3}  {}",
            r.round,
            r.lr,
            r.train_loss,
            r.server_loss,
            r.client_grad_norm.unwrap_or(0.0),
            r.accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "-".into())
        );
    }
    let steps = rounds * n_clients * h;
    println!("\nfinal accuracy  : {:.1}%", rec.final_accuracy * 100.0);
    println!("loss            : {:.3} -> {:.3}", rec.rounds[0].train_loss,
        rec.rounds.last().unwrap().train_loss);
    println!("communication   : {:.4} GB", rec.total_gb());
    println!("wall-clock      : {wall:.1} s  ({:.0} ms / client step incl. server+eval)",
        wall * 1000.0 / steps as f64);

    let mut csv = Csv::new(&["round", "train_loss", "server_loss", "accuracy"]);
    for r in &rec.rounds {
        csv.row(&[
            r.round.to_string(),
            format!("{:.5}", r.train_loss),
            format!("{:.5}", r.server_loss),
            r.accuracy.map(|a| format!("{a:.4}")).unwrap_or_default(),
        ]);
    }
    csv.write_to(std::path::Path::new("results/e2e_cifar_loss.csv"))?;
    println!("loss curve      : results/e2e_cifar_loss.csv");

    // The e2e run must actually have learned something.
    assert!(
        rec.rounds.last().unwrap().train_loss < rec.rounds[0].train_loss,
        "loss did not decrease"
    );
    assert!(rec.final_accuracy > 0.2, "accuracy {} too low", rec.final_accuracy);
    println!("e2e OK");
    Ok(())
}
