//! Fig. 3 — the asynchronous server-training timeline.
//!
//! Runs a few CSE-FSL rounds and a few SplitFed (FSL_MC) rounds under
//! identical heterogeneous client profiles, renders both Gantt charts,
//! and reports the metrics the paper argues about: the server processes
//! CSE-FSL arrivals event-triggered as they land (no barrier), while the
//! SplitFed clients block on per-batch gradient round trips.
//!
//!     cargo run --release --example async_timeline

use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{train_test, SyntheticSpec};
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::artifacts_dir;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(artifacts_dir())
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    let rt = PjrtRuntime::new()?;
    let engine = PjrtEngine::new(rt.clone(), &manifest, "cifar", "cnn27")?;
    let cfg_ds = manifest.config("cifar")?;
    let (train, test) = train_test(&SyntheticSpec::cifar_like(), 500, 100, 11);

    let mut report = Vec::new();
    for (method, h, rounds) in [(Method::CseFsl, 5usize, 2usize), (Method::FslMc, 1, 6)] {
        let partition = iid(&train, 5, &mut Rng::new(4));
        let cfg = TrainConfig {
            rounds,
            agg_every: rounds,
            lr0: 0.01,
            eval_every: 0,
            ..TrainConfig::new(method).with_h(h)
        };
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition,
            net: NetModel::edge_default(),
            client_layout: Some(&cfg_ds.client_layout),
            server_layout: Some(&cfg_ds.server_layout),
            aux_layout: Some(&cfg_ds.aux("cnn27")?.layout),
            label: format!("{method}"),
        };
        let mut trainer = Trainer::new(&engine, cfg, setup)?;
        let rec = trainer.run()?;
        println!("== {} timeline (heterogeneous clients, seed-fixed) ==", method);
        println!("{}", trainer.timeline.ascii_gantt(110));
        println!(
            "simulated time {:.3}s   server idle {:.1}%   straggler spread {:.3}s\n",
            rec.sim_time,
            rec.server_idle_fraction * 100.0,
            trainer.timeline.straggler_spread()
        );
        report.push((method, rec.sim_time));
    }
    println!(
        "note: {} clients never wait for gradients (fire-and-forget uploads; the\n\
         server consumes the dataQueue whenever data arrives), while {} blocks\n\
         every client on its per-batch server round trip.",
        report[0].0, report[1].0
    );
    Ok(())
}
