//! Non-IID federated split learning on the writer-structured F-EMNIST
//! substitute: shows the per-client label skew the writer partition
//! induces, then trains CSE-FSL on the IID and non-IID splits and
//! reports the gap (the paper's Fig. 5a-vs-5b contrast).
//!
//!     cargo run --release --example femnist_noniid

use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::femnist::{train_test, train_test_iid, FemnistSpec};
use cse_fsl::data::partition::{by_writer, equalize, iid};
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::artifacts_dir;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(artifacts_dir())
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    let rt = PjrtRuntime::new()?;
    let engine = PjrtEngine::new(rt.clone(), &manifest, "femnist", "cnn8")?;
    let cfg_ds = manifest.config("femnist")?;
    let n_clients = 5;
    let spec = FemnistSpec { writers: 25, samples_per_writer: 40, ..FemnistSpec::default_like() };

    // --- show the skew
    let (train_w, _) = train_test(&spec, 10, 3);
    let mut rng = Rng::new(5);
    let part_w = by_writer(&train_w, n_clients, &mut rng);
    println!("== writer partition: per-client top-3 label shares ==");
    for (ci, hist) in part_w.label_histograms(&train_w).iter().enumerate() {
        let total: usize = hist.iter().sum();
        let mut pairs: Vec<(usize, usize)> =
            hist.iter().cloned().enumerate().filter(|&(_, c)| c > 0).collect();
        pairs.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
        let top: Vec<String> = pairs
            .iter()
            .take(3)
            .map(|&(cls, c)| format!("class{cls}:{:.0}%", 100.0 * c as f64 / total as f64))
            .collect();
        println!("  client {ci}: {} samples, {}", total, top.join(" "));
    }

    // --- train on both splits
    let mut results = Vec::new();
    for (tag, noniid) in [("IID", false), ("non-IID (writer)", true)] {
        let (train, test) = if noniid {
            train_test(&spec, 15, 3)
        } else {
            train_test_iid(&spec, 600, 3)
        };
        let mut rng = Rng::new(5);
        let mut partition = if noniid {
            by_writer(&train, n_clients, &mut rng)
        } else {
            iid(&train, n_clients, &mut rng)
        };
        equalize(&mut partition);
        let cfg = TrainConfig {
            rounds: 120,
            agg_every: 5,
            lr0: 0.05,
            eval_every: 30,
            eval_max_batches: 20,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        };
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition,
            net: NetModel::edge_default(),
            client_layout: Some(&cfg_ds.client_layout),
            server_layout: Some(&cfg_ds.server_layout),
            aux_layout: Some(&cfg_ds.aux("cnn8")?.layout),
            label: tag.into(),
        };
        let mut trainer = Trainer::new(&engine, cfg, setup)?;
        let rec = trainer.run()?;
        println!(
            "\n{tag}: final accuracy {:.1}% (loss {:.2} -> {:.2})",
            rec.final_accuracy * 100.0,
            rec.rounds[0].train_loss,
            rec.rounds.last().unwrap().train_loss
        );
        results.push(rec.final_accuracy);
    }
    println!(
        "\nIID-vs-non-IID gap: {:.1} pp (positive gap expected — unseen writer styles +\nlabel skew make the federated problem harder, as in the paper's Fig. 5)",
        (results[0] - results[1]) * 100.0
    );
    Ok(())
}
