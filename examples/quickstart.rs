//! Quickstart: train CSE-FSL on the synthetic F-EMNIST task with the real
//! AOT/PJRT engine — the smallest end-to-end demonstration of the stack.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Five clients train their client-side models locally with a CNN+MLP
//! auxiliary network (h = 2 batches per upload), the server updates its
//! SINGLE shared server-side model as each smashed batch arrives, and the
//! client/auxiliary models are FedAvg'd once per epoch.

use std::time::Instant;

use cse_fsl::coordinator::config::TrainConfig;
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::femnist::FemnistSpec;
use cse_fsl::data::partition::{by_writer, equalize};
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::{artifacts_dir, SplitEngine};
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    let rt = PjrtRuntime::new()?;
    let engine = PjrtEngine::new(rt.clone(), &manifest, "femnist", "cnn8")?;
    let cfg_ds = manifest.config("femnist")?;

    // Synthetic writer-structured data (see DESIGN.md §Substitutions),
    // partitioned by writer => naturally non-IID.
    let spec = FemnistSpec { writers: 15, samples_per_writer: 20, ..FemnistSpec::default_like() };
    let (train, test) = cse_fsl::data::femnist::train_test(&spec, 10, 1);
    let mut prng = Rng::new(3);
    let mut partition = by_writer(&train, 5, &mut prng);
    equalize(&mut partition);

    let cfg = TrainConfig {
        rounds: 12,
        agg_every: 3,
        lr0: 0.02,
        eval_every: 3,
        eval_max_batches: 10,
        ..TrainConfig::new(Method::CseFsl).with_h(2)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition,
        net: NetModel::edge_default(),
        client_layout: Some(&cfg_ds.client_layout),
        server_layout: Some(&cfg_ds.server_layout),
        aux_layout: Some(&cfg_ds.aux("cnn8")?.layout),
        label: "quickstart".into(),
    };

    println!("== CSE-FSL quickstart: femnist/cnn8, 5 clients, h=2 ==");
    println!(
        "client params {}  server params {}  aux params {}",
        engine.client_size(),
        engine.server_size(),
        engine.aux_size()
    );
    let t0 = Instant::now();
    let mut trainer = Trainer::new(&engine, cfg, setup)?;
    let rec = trainer.run()?;
    let wall = t0.elapsed();

    println!("\nround  train_loss  server_loss  accuracy");
    for r in rec.rounds.iter() {
        println!(
            "{:>5}  {:>10.4}  {:>11.4}  {}",
            r.round,
            r.train_loss,
            r.server_loss,
            r.accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "-".into())
        );
    }
    println!("\nfinal accuracy      : {:.1}%", rec.final_accuracy * 100.0);
    println!("communication       : {:.3} MB up, {:.3} MB down",
        rec.total_up_bytes as f64 / 1e6, rec.total_down_bytes as f64 / 1e6);
    println!("server storage      : {:.2} M params (independent of client count)",
        rec.server_storage_params as f64 / 1e6);
    println!("simulated time      : {:.2} s   server idle {:.0}%",
        rec.sim_time, rec.server_idle_fraction * 100.0);
    println!("wall-clock          : {:.1} s ({} PJRT executables compiled)",
        wall.as_secs_f64(), rt.compiles());
    println!("\nasync timeline (first rounds):\n{}",
        trainer.timeline.ascii_gantt(100));
    Ok(())
}
